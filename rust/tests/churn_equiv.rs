//! Membership state machine vs dense reference, under churn.
//!
//! PR 7 teaches [`ServerState`] time-varying membership: departures consume
//! a rejoin schedule, re-admissions ride commit replies (or an event-driven
//! `on_worker_joined`), and the commit log truncates over live cursors.
//! This suite pins that machinery against the obvious reference — one dense
//! O(d) accumulator per worker plus an explicit live set — across
//! randomized update orders, loss injection times and rejoin schedules:
//!
//!   * every action matches (Wait vs Commit vs error, round, full_barrier,
//!     finished, reply set),
//!   * every reply — member replies AND admission replies — is
//!     byte-identical on the wire,
//!   * a rejoined worker's admission reply equals a fresh worker's
//!     cursor-0 materialization (`from_dense` of the ordered commit sum),
//!   * cursors never pin the log: live entries stay ≤ T and drop to zero
//!     at every full barrier,
//!   * rejoin counts, failure lists and the membership timeline agree,
//!   * the final model `w` is bit-for-bit identical.

use acpd::linalg::sparse::SparseVec;
use acpd::protocol::messages::{DeltaMsg, ModelDelta, UpdateMsg};
use acpd::protocol::server::{FailPolicy, ServerAction, ServerConfig, ServerState};
use acpd::testing::forall;
use acpd::util::rng::Pcg64;

/// What the reference wants the runtime to do (mirror of [`ServerAction`],
/// plus an explicit error arm so predicted degrade-failures compare too).
enum RefAction {
    Wait,
    Commit {
        replies: Vec<DeltaMsg>,
        round: u64,
        full_barrier: bool,
        finished: bool,
    },
    Error,
}

/// Reference server with membership: dense per-worker accumulators, an
/// explicit live set, and the same rejoin-schedule semantics — all O(K·d),
/// all eager.
struct DenseChurnServer {
    cfg: ServerConfig,
    w: Vec<f32>,
    pending: Vec<Vec<f32>>,
    inbox: Vec<Option<ModelDelta>>,
    in_group: usize,
    t: usize,
    l: usize,
    total_rounds: u64,
    finished: bool,
    live: Vec<bool>,
    schedule: Vec<Vec<u64>>,
    episodes: Vec<usize>,
    rejoin_at: Vec<Option<u64>>,
    rejoins: u64,
    timeline: Vec<(u64, usize, bool)>,
}

impl DenseChurnServer {
    fn new(cfg: ServerConfig, dim: usize, schedule: Vec<Vec<u64>>) -> Self {
        let k = cfg.workers;
        DenseChurnServer {
            w: vec![0.0; dim],
            pending: vec![vec![0.0; dim]; k],
            inbox: vec![None; k],
            in_group: 0,
            t: 0,
            l: 0,
            total_rounds: 0,
            finished: false,
            live: vec![true; k],
            schedule,
            episodes: vec![0; k],
            rejoin_at: vec![None; k],
            rejoins: 0,
            timeline: Vec::new(),
            cfg,
        }
    }

    fn live_count(&self) -> usize {
        self.live.iter().filter(|&&a| a).count()
    }

    fn is_full_barrier(&self) -> bool {
        self.t == self.cfg.period - 1
    }

    fn barrier_met(&self) -> bool {
        if self.is_full_barrier() {
            self.in_group == self.live_count()
        } else {
            self.in_group >= self.cfg.group.min(self.live_count()).max(1)
        }
    }

    fn admit(&mut self, k: usize) -> DeltaMsg {
        assert!(!self.live[k]);
        self.rejoin_at[k] = None;
        self.live[k] = true;
        self.pending[k].fill(0.0);
        self.rejoins += 1;
        self.timeline.push((self.total_rounds, k, true));
        DeltaMsg {
            worker: k as u32,
            server_round: self.total_rounds,
            shutdown: self.finished,
            delta: ModelDelta::from_dense(&self.w),
        }
    }

    fn commit_group(&mut self) -> RefAction {
        let gamma = self.cfg.gamma;
        let full_barrier = self.is_full_barrier();
        let members: Vec<usize> = (0..self.cfg.workers)
            .filter(|&k| self.inbox[k].is_some())
            .collect();
        let mut g = vec![0.0f32; self.w.len()];
        for &k in &members {
            let f = self.inbox[k].take().unwrap();
            f.add_scaled_into(&mut g, gamma);
        }
        for (wi, gi) in self.w.iter_mut().zip(&g) {
            *wi += *gi;
        }
        for pend in self.pending.iter_mut() {
            for (p, gi) in pend.iter_mut().zip(&g) {
                *p += *gi;
            }
        }
        self.in_group = 0;
        self.total_rounds += 1;
        if full_barrier {
            self.t = 0;
            self.l += 1;
        } else {
            self.t += 1;
        }
        let finished = self.l >= self.cfg.outer_rounds;
        self.finished = finished;
        let mut replies: Vec<DeltaMsg> = members
            .iter()
            .map(|&k| {
                let delta = ModelDelta::from_dense(&self.pending[k]);
                self.pending[k].fill(0.0);
                DeltaMsg {
                    worker: k as u32,
                    server_round: self.total_rounds,
                    shutdown: finished,
                    delta,
                }
            })
            .collect();
        if !finished {
            for k in 0..self.cfg.workers {
                if self.rejoin_at[k].map_or(false, |due| due <= self.total_rounds) {
                    let reply = self.admit(k);
                    replies.push(reply);
                }
            }
        }
        RefAction::Commit {
            replies,
            round: self.total_rounds,
            full_barrier,
            finished,
        }
    }

    fn on_update(&mut self, msg: UpdateMsg) -> RefAction {
        assert!(!self.finished);
        let k = msg.worker as usize;
        if !self.live[k] {
            return RefAction::Wait;
        }
        assert!(self.inbox[k].is_none());
        self.inbox[k] = Some(msg.update);
        self.in_group += 1;
        if !self.barrier_met() {
            return RefAction::Wait;
        }
        self.commit_group()
    }

    fn on_lost(&mut self, k: usize) -> RefAction {
        if self.finished || !self.live[k] {
            return RefAction::Wait;
        }
        self.live[k] = false;
        self.timeline.push((self.total_rounds, k, false));
        if let Some(&gap) = self.schedule[k].get(self.episodes[k]) {
            self.rejoin_at[k] = Some(self.total_rounds + gap);
        }
        self.episodes[k] += 1;
        if self.inbox[k].take().is_some() {
            self.in_group -= 1;
        }
        let pending = self.rejoin_at.iter().any(|r| r.is_some());
        if self.live_count() < self.cfg.group && !pending {
            return RefAction::Error;
        }
        if self.in_group > 0 && self.barrier_met() {
            return self.commit_group();
        }
        if self.live_count() == 0 {
            let (_, next) = (0..self.cfg.workers)
                .filter_map(|j| self.rejoin_at[j].map(|due| (due, j)))
                .min()
                .expect("pending rejoin exists when live == 0");
            let reply = self.admit(next);
            return RefAction::Commit {
                replies: vec![reply],
                round: self.total_rounds,
                full_barrier: false,
                finished: false,
            };
        }
        RefAction::Wait
    }

    fn timeline_string(&self) -> String {
        let mut out = String::new();
        for &(round, wid, joined) in &self.timeline {
            if !out.is_empty() {
                out.push(';');
            }
            let sign = if joined { '+' } else { '-' };
            out.push_str(&format!("w{wid}{sign}@r{round}"));
        }
        out
    }
}

fn random_update(rng: &mut Pcg64, worker: usize, d: usize, max_nnz: usize) -> UpdateMsg {
    let mut idx: Vec<u32> = (0..d as u32).collect();
    rng.shuffle(&mut idx);
    idx.truncate(rng.next_below(max_nnz.min(d) as u32 + 1) as usize);
    idx.sort_unstable();
    let val: Vec<f32> = idx.iter().map(|_| rng.next_normal() as f32).collect();
    UpdateMsg::from_sparse(worker as u32, 0, SparseVec::new(d, idx, val))
}

#[derive(Debug)]
struct Case {
    workers: usize,
    group: usize,
    period: usize,
    outer_rounds: usize,
    d: usize,
    max_nnz: usize,
    /// `schedule[k]`: away gaps consumed per departure; exhausted ⇒
    /// permanent (the legacy kill/flaky shape).
    schedule: Vec<Vec<u64>>,
    /// Permille chance per step of injecting a loss instead of an update.
    loss_permille: u32,
    stream_seed: u64,
}

/// Compare one production action against the reference's, enforcing
/// byte-identical replies; returns `None` on mismatch, `Some(finished)`
/// otherwise.  `sent` is cleared for every member reply (admission replies
/// carry no in-flight update to clear — but clearing is idempotent).
fn actions_match(
    a: &ServerAction,
    b: &RefAction,
    sent: &mut [bool],
) -> Option<bool> {
    match (a, b) {
        (ServerAction::Wait, RefAction::Wait) => Some(false),
        (
            ServerAction::Commit {
                replies,
                round,
                full_barrier,
                finished,
            },
            RefAction::Commit {
                replies: ref_replies,
                round: ref_round,
                full_barrier: ref_full,
                finished: ref_fin,
            },
        ) => {
            if (round, full_barrier, finished) != (ref_round, ref_full, ref_fin) {
                return None;
            }
            if replies.len() != ref_replies.len() {
                return None;
            }
            for (r, rr) in replies.iter().zip(ref_replies) {
                if r != rr || r.encode() != rr.encode() {
                    return None;
                }
                sent[r.worker as usize] = false;
            }
            Some(*finished)
        }
        _ => None,
    }
}

#[test]
fn prop_membership_machine_matches_dense_reference() {
    forall(
        0xC4A2_0007,
        60,
        |rng, sz| {
            let workers = 2 + rng.next_below(4) as usize;
            let group = 1 + rng.next_below(workers as u32) as usize;
            let period = 1 + rng.next_below(4) as usize;
            let outer_rounds = 1 + rng.next_below(3) as usize;
            let d = 1 + rng.next_below(sz.0 as u32 * 3 + 1) as usize;
            let max_nnz = 1 + rng.next_below(d as u32) as usize;
            // about half the workers can come back, one to three times,
            // after short away gaps; the rest leave for good (kill/flaky)
            let schedule = (0..workers)
                .map(|_| {
                    if rng.next_below(2) == 0 {
                        (0..1 + rng.next_below(3))
                            .map(|_| 1 + rng.next_below(4) as u64)
                            .collect()
                    } else {
                        Vec::new()
                    }
                })
                .collect();
            Case {
                workers,
                group,
                period,
                outer_rounds,
                d,
                max_nnz,
                schedule,
                loss_permille: 50 + rng.next_below(200),
                stream_seed: rng.next_u64(),
            }
        },
        |case| {
            let cfg = ServerConfig {
                workers: case.workers,
                group: case.group,
                period: case.period,
                outer_rounds: case.outer_rounds,
                gamma: 0.5,
                policy: FailPolicy::Degrade,
                shards: 1,
            };
            let mut log_srv = ServerState::new(cfg.clone(), case.d);
            log_srv.set_rejoin_schedule(case.schedule.clone());
            let mut dense_srv =
                DenseChurnServer::new(cfg, case.d, case.schedule.clone());
            let mut rng = Pcg64::new(case.stream_seed);
            let mut sent = vec![false; case.workers];
            let mut guard = 0usize;
            while !log_srv.finished() {
                guard += 1;
                if guard > 5_000 {
                    return false; // stuck: barrier never met
                }
                let free: Vec<usize> = (0..case.workers)
                    .filter(|&i| log_srv.is_live(i) && !sent[i])
                    .collect();
                // losses hit any live worker — with or without an in-flight
                // update, both removal paths matter
                let live: Vec<usize> =
                    (0..case.workers).filter(|&i| log_srv.is_live(i)).collect();
                if live.is_empty() {
                    return false; // live==0 must never persist (rescue path)
                }
                let lose = !live.is_empty()
                    && rng.next_below(1000) < case.loss_permille;
                let (a, b) = if lose || free.is_empty() {
                    // free can only be empty if an un-met barrier holds every
                    // live worker in-flight — impossible; losing one instead
                    // keeps the driver honest rather than masking it
                    if !lose && free.is_empty() {
                        return false;
                    }
                    let wid = live[rng.next_below(live.len() as u32) as usize];
                    sent[wid] = false;
                    let ra = log_srv.on_worker_lost(wid, "injected");
                    let rb = dense_srv.on_lost(wid);
                    match ra {
                        // both must agree the run dies here (live < B, no
                        // pending rejoin) — that agreement IS the property
                        Err(_) => return matches!(rb, RefAction::Error),
                        Ok(a) => {
                            if matches!(rb, RefAction::Error) {
                                return false;
                            }
                            (a, rb)
                        }
                    }
                } else {
                    let wid = free[rng.next_below(free.len() as u32) as usize];
                    let msg = random_update(&mut rng, wid, case.d, case.max_nnz);
                    sent[wid] = true;
                    (log_srv.on_update(msg.clone()), dense_srv.on_update(msg))
                };
                if actions_match(&a, &b, &mut sent).is_none() {
                    return false;
                }
                // cursors must never pin the log past one full-barrier period
                if log_srv.live_log_entries() > case.period {
                    return false;
                }
                if let ServerAction::Commit {
                    full_barrier: true, ..
                } = a
                {
                    // every live cursor advanced past the whole log
                    if log_srv.live_log_entries() != 0 {
                        return false;
                    }
                }
            }
            // membership accounting agrees end-to-end
            if log_srv.rejoins() != dense_srv.rejoins {
                return false;
            }
            if log_srv.membership_timeline() != dense_srv.timeline_string() {
                return false;
            }
            if log_srv.failures().len()
                != dense_srv.timeline.iter().filter(|&&(_, _, j)| !j).count()
            {
                return false;
            }
            if !dense_srv.finished {
                return false;
            }
            // bit-for-bit identical final model
            log_srv.w() == dense_srv.w.as_slice()
        },
    );
}

/// Event-driven admission (`on_worker_joined`, the TCP reconnect path): a
/// permanently-departed worker that reconnects is re-admitted with a
/// full-model reply bit-identical to a fresh worker's cursor-0
/// materialization, exactly once, and never while live, finished, or owned
/// by a scheduled rejoin.
#[test]
fn reconnect_admission_matches_fresh_worker_bootstrap() {
    let cfg = ServerConfig {
        workers: 3,
        group: 2,
        period: 2,
        outer_rounds: 4,
        gamma: 1.0,
        policy: FailPolicy::Degrade,
        shards: 1,
    };
    let d = 12;
    let mut srv = ServerState::new(cfg.clone(), d);
    let mut dense = DenseChurnServer::new(cfg, d, vec![Vec::new(); 3]);
    let mut rng = Pcg64::new(0xADA117);
    let mut sent = vec![false; 3];
    // run two commits with everyone live, then drop worker 2 for good
    let mut commits = 0;
    while commits < 2 {
        let wid = (0..3).find(|&i| !sent[i]).unwrap();
        let msg = random_update(&mut rng, wid, d, 6);
        sent[wid] = true;
        let a = srv.on_update(msg.clone());
        let b = dense.on_update(msg);
        assert!(actions_match(&a, &b, &mut sent).is_some(), "healthy prefix diverged");
        if let ServerAction::Commit { .. } = a {
            commits += 1;
        }
    }
    assert!(matches!(srv.on_worker_lost(2, "gone").unwrap(), ServerAction::Wait));
    assert!(matches!(dense.on_lost(2), RefAction::Wait));
    assert_eq!(srv.live_workers(), 2);
    // a live worker or an out-of-range id is never admitted
    assert!(srv.on_worker_joined(0).is_none());
    assert!(srv.on_worker_joined(99).is_none());
    // the reconnect: admitted once, with the full model on the wire —
    // byte-identical to a fresh worker's cursor-0 materialization, which
    // the dense reference's `w` (the ordered commit sum) spells out
    let reply = srv.on_worker_joined(2).expect("reconnect admits");
    let fresh = DeltaMsg {
        worker: 2,
        server_round: srv.total_rounds(),
        shutdown: false,
        delta: ModelDelta::from_dense(&dense.w),
    };
    assert_eq!(reply, fresh);
    assert_eq!(reply.encode(), fresh.encode());
    assert!(srv.is_live(2));
    assert_eq!(srv.live_workers(), 3);
    assert_eq!(srv.rejoins(), 1);
    assert!(srv.membership_timeline().contains("w2-@r"));
    assert!(srv.membership_timeline().contains("w2+@r"));
    // idempotence: the worker is live again, a second hello is a no-op
    assert!(srv.on_worker_joined(2).is_none());
    // a scheduled rejoin owns its admission timing: reconnects are refused
    srv.set_rejoin_schedule(vec![Vec::new(), vec![5], Vec::new()]);
    assert!(matches!(srv.on_worker_lost(1, "churn").unwrap(), ServerAction::Wait));
    assert_eq!(srv.pending_rejoins(), 1);
    assert!(srv.on_worker_joined(1).is_none(), "schedule owns admission");
    assert_eq!(srv.rejoins(), 1);
}

/// An update racing ahead of its own loss notice is dropped, and a dead
/// worker's cursor never pins the log (truncation over live cursors only).
#[test]
fn dead_worker_updates_drop_and_cursors_unpin() {
    let cfg = ServerConfig {
        workers: 3,
        group: 1,
        period: 4,
        outer_rounds: 2,
        gamma: 1.0,
        policy: FailPolicy::Degrade,
        shards: 1,
    };
    let d = 8;
    let mut srv = ServerState::new(cfg, d);
    let mut rng = Pcg64::new(0xD0A);
    // worker 2 departs before ever being included: its cursor stays 0
    assert!(matches!(srv.on_worker_lost(2, "early").unwrap(), ServerAction::Wait));
    // its straggling update must not enter any commit
    let msg = random_update(&mut rng, 2, d, 4);
    assert!(matches!(srv.on_update(msg), ServerAction::Wait));
    // workers 0/1 alone drive the run; the dead cursor-0 worker must not
    // leak one log entry per commit
    let mut sent = [false; 2];
    while !srv.finished() {
        let wid = (0..2).find(|&i| !sent[i]).unwrap();
        let msg = random_update(&mut rng, wid, d, 4);
        sent[wid] = true;
        if let ServerAction::Commit { replies, .. } = srv.on_update(msg) {
            for r in &replies {
                sent[r.worker as usize] = false;
            }
            assert!(
                srv.live_log_entries() <= 4,
                "dead cursor pinned the log: {} entries",
                srv.live_log_entries()
            );
        }
    }
    assert_eq!(srv.total_rounds(), 8); // outer_rounds x period, degraded or not
    assert_eq!(srv.failures().len(), 1);
    assert_eq!(srv.rejoins(), 0);
}
