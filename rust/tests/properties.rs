//! Property tests (via `acpd::testing::forall`) for the two mechanisms the
//! paper's byte accounting stands on:
//!
//! 1. the top-ρd filter with error feedback, *iterated across rounds*:
//!    every round splits its input exactly (kept + residual == input,
//!    bit-for-bit), and once inputs stop the residual fully drains within
//!    ceil(d/k) rounds — the filtered-out mass is delayed, never lost and
//!    never accumulating without bound;
//!
//! 2. the `util::binio` wire codec: random `UpdateMsg`/`DeltaMsg` values
//!    roundtrip exactly, and `wire_bytes()` — the number the simulator
//!    charges to the α-β cost model — equals the actual encoded length.

use acpd::filter::{filter_topk, FilterScratch};
use acpd::linalg::sparse::SparseVec;
use acpd::protocol::messages::{DeltaMsg, ModelDelta, UpdateMsg};
use acpd::testing::{forall, gens, Size};
use acpd::util::rng::Pcg64;

#[test]
fn prop_error_feedback_conserves_mass_across_rounds() {
    forall(
        0xEF_0001,
        80,
        |rng, sz| {
            let d = 4 + rng.next_below(sz.0 as u32 * 4 + 1) as usize;
            let k = 1 + rng.next_below(d as u32) as usize;
            let rounds = 1 + rng.next_below(12) as usize;
            let stream_seed = rng.next_u64();
            (d, k, rounds, stream_seed)
        },
        |&(d, k, rounds, stream_seed)| {
            let mut rng = Pcg64::new(stream_seed);
            let mut resid = vec![0.0f32; d];
            let mut scratch = FilterScratch::default();
            for _ in 0..rounds {
                // new local update, bounded entries
                let u: Vec<f32> = (0..d).map(|_| (rng.next_f64() as f32) * 2.0 - 1.0).collect();
                // error feedback: the filter input is update + carried residual
                let mut delta: Vec<f32> =
                    resid.iter().zip(&u).map(|(r, x)| r + x).collect();
                let before = delta.clone();
                let sent = filter_topk(&mut delta, k, &mut scratch);
                // budget
                if sent.nnz() > k {
                    return false;
                }
                // exact per-round conservation: sent + residual == input.
                // The filter is pure selection (no arithmetic), so adding the
                // sent coordinates back into the residual must reproduce the
                // input bit-for-bit.
                let mut recon = delta.clone();
                sent.add_into(&mut recon, 1.0);
                if recon != before {
                    return false;
                }
                resid = delta;
            }
            // drain: with no new input, delta == residual each round and the
            // filter ships >= min(k, nnz) coordinates verbatim, so the
            // residual must reach exactly zero within ceil(d/k) rounds —
            // this is the "never grows unboundedly" half of error feedback.
            let budget = (d + k - 1) / k + 1;
            for _ in 0..budget {
                if resid.iter().all(|&x| x == 0.0) {
                    break;
                }
                let _ = filter_topk(&mut resid, k, &mut scratch);
            }
            resid.iter().all(|&x| x == 0.0)
        },
    );
}

#[test]
fn prop_residual_dominated_by_sent_coordinates() {
    // At every round the filter keeps the largest magnitudes: no residual
    // entry may exceed the smallest sent entry.  Run the *iterated* system
    // so the property covers error-feedback inputs, not just fresh vectors.
    forall(
        0xEF_0002,
        80,
        |rng, sz| {
            let d = 8 + rng.next_below(sz.0 as u32 * 4 + 1) as usize;
            let k = 1 + rng.next_below((d / 2) as u32) as usize;
            let stream_seed = rng.next_u64();
            (d, k, stream_seed)
        },
        |&(d, k, stream_seed)| {
            let mut rng = Pcg64::new(stream_seed);
            let mut resid = vec![0.0f32; d];
            let mut scratch = FilterScratch::default();
            for _ in 0..8 {
                let mut delta: Vec<f32> = resid
                    .iter()
                    .map(|r| r + (rng.next_f64() as f32) * 2.0 - 1.0)
                    .collect();
                let sent = filter_topk(&mut delta, k, &mut scratch);
                let min_sent = sent.val.iter().map(|v| v.abs()).fold(f32::INFINITY, f32::min);
                let max_kept = delta.iter().map(|v| v.abs()).fold(0.0f32, f32::max);
                if sent.nnz() > 0 && max_kept > min_sent {
                    return false;
                }
                resid = delta;
            }
            true
        },
    );
}

fn random_sparse(rng: &mut Pcg64, sz: Size) -> SparseVec {
    let dim = 4 + rng.next_below(sz.0 as u32 * 30 + 1) as usize;
    let idx = gens::sparse_pattern(rng, Size(sz.0.min(dim)), dim);
    let val: Vec<f32> = idx.iter().map(|_| rng.next_normal() as f32).collect();
    SparseVec::new(dim, idx, val)
}

#[test]
fn prop_update_msg_wire_bytes_match_encoding() {
    forall(
        0xB1_0001,
        200,
        |rng, sz| {
            UpdateMsg::from_sparse(rng.next_below(128), rng.next_u64(), random_sparse(rng, sz))
        },
        |msg| {
            let buf = msg.encode();
            buf.len() == msg.wire_bytes()
                && matches!(UpdateMsg::decode(&buf), Ok(back) if back == *msg)
        },
    );
}

#[test]
fn prop_delta_msg_wire_bytes_match_encoding() {
    forall(
        0xB1_0002,
        200,
        |rng, sz| {
            let delta = if rng.next_f64() < 0.5 {
                ModelDelta::Sparse(random_sparse(rng, sz))
            } else {
                let d = 1 + rng.next_below(sz.0 as u32 * 10 + 1) as usize;
                ModelDelta::Dense((0..d).map(|_| rng.next_normal() as f32).collect())
            };
            DeltaMsg {
                worker: rng.next_below(128),
                server_round: rng.next_u64(),
                shutdown: rng.next_f64() < 0.5,
                delta,
            }
        },
        |msg| {
            let buf = msg.encode();
            buf.len() == msg.wire_bytes()
                && matches!(DeltaMsg::decode(&buf), Ok(back) if back == *msg)
        },
    );
}
