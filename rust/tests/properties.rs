//! Property tests (via `acpd::testing::forall`) for the two mechanisms the
//! paper's byte accounting stands on:
//!
//! 1. the top-ρd filter with error feedback, *iterated across rounds*:
//!    every round splits its input exactly (kept + residual == input,
//!    bit-for-bit), and once inputs stop the residual fully drains within
//!    ceil(d/k) rounds — the filtered-out mass is delayed, never lost and
//!    never accumulating without bound;
//!
//! 2. the `util::binio` wire codec: random `UpdateMsg`/`DeltaMsg` values
//!    roundtrip exactly, and `wire_bytes()` — the number the simulator
//!    charges to the α-β cost model — equals the actual encoded length;
//!
//! 3. the O(touched) epoch delta: the touched-index support the solver
//!    reports covers every coordinate the dense-reference epoch moved (no
//!    silently dropped coordinates — exact `SparseVec::from_dense`
//!    equality), and the dense-mode (ρd = 0) worker ships everything with
//!    an identically-zero residual every round.

use acpd::data::{libsvm, partition::partition_rows, synthetic, synthetic::Preset, Dataset};
use acpd::linalg::csr::CsrMatrix;
use acpd::filter::{filter_topk, FilterScratch};
use acpd::linalg::sparse::SparseVec;
use acpd::loss::LossKind;
use acpd::protocol::messages::{DeltaMsg, ModelDelta, UpdateMsg};
use acpd::protocol::worker::WorkerState;
use acpd::solver::sdca::SdcaSolver;
use acpd::solver::LocalSolver;
use acpd::testing::{forall, gens, Size};
use acpd::util::rng::Pcg64;

#[test]
fn prop_error_feedback_conserves_mass_across_rounds() {
    forall(
        0xEF_0001,
        80,
        |rng, sz| {
            let d = 4 + rng.next_below(sz.0 as u32 * 4 + 1) as usize;
            let k = 1 + rng.next_below(d as u32) as usize;
            let rounds = 1 + rng.next_below(12) as usize;
            let stream_seed = rng.next_u64();
            (d, k, rounds, stream_seed)
        },
        |&(d, k, rounds, stream_seed)| {
            let mut rng = Pcg64::new(stream_seed);
            let mut resid = vec![0.0f32; d];
            let mut scratch = FilterScratch::default();
            for _ in 0..rounds {
                // new local update, bounded entries
                let u: Vec<f32> = (0..d).map(|_| (rng.next_f64() as f32) * 2.0 - 1.0).collect();
                // error feedback: the filter input is update + carried residual
                let mut delta: Vec<f32> =
                    resid.iter().zip(&u).map(|(r, x)| r + x).collect();
                let before = delta.clone();
                let sent = filter_topk(&mut delta, k, &mut scratch);
                // budget
                if sent.nnz() > k {
                    return false;
                }
                // exact per-round conservation: sent + residual == input.
                // The filter is pure selection (no arithmetic), so adding the
                // sent coordinates back into the residual must reproduce the
                // input bit-for-bit.
                let mut recon = delta.clone();
                sent.add_into(&mut recon, 1.0);
                if recon != before {
                    return false;
                }
                resid = delta;
            }
            // drain: with no new input, delta == residual each round and the
            // filter ships >= min(k, nnz) coordinates verbatim, so the
            // residual must reach exactly zero within ceil(d/k) rounds —
            // this is the "never grows unboundedly" half of error feedback.
            let budget = (d + k - 1) / k + 1;
            for _ in 0..budget {
                if resid.iter().all(|&x| x == 0.0) {
                    break;
                }
                let _ = filter_topk(&mut resid, k, &mut scratch);
            }
            resid.iter().all(|&x| x == 0.0)
        },
    );
}

/// Error-feedback conservation extended to LAG-style skip rounds
/// (`Algorithm::AcpdLag`): a skip round folds the new update into the
/// residual WITHOUT running the filter — nothing is sent, exactly what
/// [`WorkerState`] does when a round falls under its skip threshold.
/// Across any interleaving of send and skip rounds the per-round split
/// stays exact (bit-for-bit reconstruction), and once inputs stop and
/// skipping stops the carried mass — everything the skip rounds retained
/// included — still drains to exactly zero within ceil(d/k) rounds:
/// skipped mass is delayed, never lost and never unboundedly accumulating.
#[test]
fn prop_error_feedback_conserves_mass_across_skip_rounds() {
    forall(
        0xEF_5C1F,
        80,
        |rng, sz| {
            let d = 4 + rng.next_below(sz.0 as u32 * 4 + 1) as usize;
            let k = 1 + rng.next_below(d as u32) as usize;
            let rounds = 2 + rng.next_below(12) as usize;
            let stream_seed = rng.next_u64();
            (d, k, rounds, stream_seed)
        },
        |&(d, k, rounds, stream_seed)| {
            let mut rng = Pcg64::new(stream_seed);
            let mut resid = vec![0.0f32; d];
            let mut scratch = FilterScratch::default();
            for _ in 0..rounds {
                let u: Vec<f32> = (0..d).map(|_| (rng.next_f64() as f32) * 2.0 - 1.0).collect();
                let mut delta: Vec<f32> =
                    resid.iter().zip(&u).map(|(r, x)| r + x).collect();
                let before = delta.clone();
                if rng.next_f64() < 0.4 {
                    // skip round: the filter never runs, the whole folded
                    // input is carried — conservation is the identity
                    resid = delta;
                    continue;
                }
                let sent = filter_topk(&mut delta, k, &mut scratch);
                if sent.nnz() > k {
                    return false;
                }
                // exact split on send rounds, skip rounds in the carry
                let mut recon = delta.clone();
                sent.add_into(&mut recon, 1.0);
                if recon != before {
                    return false;
                }
                resid = delta;
            }
            // drain: once inputs AND skipping stop, the residual ships
            // within the same ceil(d/k) budget as the never-skipping system
            let budget = (d + k - 1) / k + 1;
            for _ in 0..budget {
                if resid.iter().all(|&x| x == 0.0) {
                    break;
                }
                let _ = filter_topk(&mut resid, k, &mut scratch);
            }
            resid.iter().all(|&x| x == 0.0)
        },
    );
}

#[test]
fn prop_residual_dominated_by_sent_coordinates() {
    // At every round the filter keeps the largest magnitudes: no residual
    // entry may exceed the smallest sent entry.  Run the *iterated* system
    // so the property covers error-feedback inputs, not just fresh vectors.
    forall(
        0xEF_0002,
        80,
        |rng, sz| {
            let d = 8 + rng.next_below(sz.0 as u32 * 4 + 1) as usize;
            let k = 1 + rng.next_below((d / 2) as u32) as usize;
            let stream_seed = rng.next_u64();
            (d, k, stream_seed)
        },
        |&(d, k, stream_seed)| {
            let mut rng = Pcg64::new(stream_seed);
            let mut resid = vec![0.0f32; d];
            let mut scratch = FilterScratch::default();
            for _ in 0..8 {
                let mut delta: Vec<f32> = resid
                    .iter()
                    .map(|r| r + (rng.next_f64() as f32) * 2.0 - 1.0)
                    .collect();
                let sent = filter_topk(&mut delta, k, &mut scratch);
                let min_sent = sent.val.iter().map(|v| v.abs()).fold(f32::INFINITY, f32::min);
                let max_kept = delta.iter().map(|v| v.abs()).fold(0.0f32, f32::max);
                if sent.nnz() > 0 && max_kept > min_sent {
                    return false;
                }
                resid = delta;
            }
            true
        },
    );
}

fn random_sparse(rng: &mut Pcg64, sz: Size) -> SparseVec {
    let dim = 4 + rng.next_below(sz.0 as u32 * 30 + 1) as usize;
    let idx = gens::sparse_pattern(rng, Size(sz.0.min(dim)), dim);
    let val: Vec<f32> = idx.iter().map(|_| rng.next_normal() as f32).collect();
    SparseVec::new(dim, idx, val)
}

#[test]
fn prop_update_msg_wire_bytes_match_encoding() {
    forall(
        0xB1_0001,
        200,
        |rng, sz| {
            UpdateMsg::from_sparse(rng.next_below(128), rng.next_u64(), random_sparse(rng, sz))
        },
        |msg| {
            let buf = msg.encode();
            buf.len() == msg.wire_bytes()
                && matches!(UpdateMsg::decode(&buf), Ok(back) if back == *msg)
        },
    );
}

fn solver_pair(d: usize, n: usize, data_seed: u64, rng_seed: u64) -> (SdcaSolver, SdcaSolver) {
    let mut spec = Preset::Rcv1Small.spec();
    spec.n = n;
    spec.d = d;
    let ds = synthetic::generate(&spec, data_seed);
    let build = |seed| {
        let part = partition_rows(&ds, 1, None).into_iter().next().unwrap();
        SdcaSolver::new(part, LossKind::Square, 0.01, n, 1.0, 1.0, Pcg64::new(seed))
    };
    (build(rng_seed), build(rng_seed))
}

/// Mass-conservation prerequisite for the sparse worker path: the epoch
/// delta's touched support must cover EVERY coordinate the epoch actually
/// moved.  A dropped coordinate would silently leak update mass out of the
/// `sent + residual == (1/λn)AᵀΔα` ledger, so we require exact equality
/// with `from_dense` of the dense-reference epoch — values and support.
#[test]
fn prop_epoch_delta_support_covers_dense_reference() {
    forall(
        0xDE17_0001,
        30,
        |rng, sz| {
            let d = 16 + rng.next_below(sz.0 as u32 * 4 + 1) as usize;
            let n = 16 + rng.next_below(48) as usize;
            let h = 1 + rng.next_below(96) as usize;
            let epochs = 1 + rng.next_below(3) as usize;
            (d, n, h, epochs, rng.next_u64(), rng.next_u64())
        },
        |&(d, n, h, epochs, data_seed, rng_seed)| {
            let (mut sparse, mut dense_ref) = solver_pair(d, n, data_seed, rng_seed);
            let w_eff = vec![0.0f32; d];
            for _ in 0..epochs {
                let idx = sparse.draw_schedule(h);
                if idx != dense_ref.draw_schedule(h) {
                    return false;
                }
                let sv = sparse.solve_epoch_with_schedule(&w_eff, &idx, None);
                let dw = dense_ref.solve_epoch_with_schedule_dense(&w_eff, &idx);
                // exact support + value equality; in particular every
                // nonzero of the dense delta appears in the sparse support
                if sv != SparseVec::from_dense(&dw) {
                    return false;
                }
                if sparse.alpha() != dense_ref.alpha() {
                    return false;
                }
            }
            true
        },
    );
}

/// Dense-mode (ρd = 0) regression pin: every round ships the WHOLE
/// accumulated update — the residual and its support are identically empty
/// after every round, and the conservation ledger closes with the sent
/// mass alone.
#[test]
fn dense_mode_ships_everything_every_round() {
    let d = 300;
    let n = 96;
    let mut spec = Preset::Rcv1Small.spec();
    spec.n = n;
    spec.d = d;
    let ds = synthetic::generate(&spec, 7);
    let part = partition_rows(&ds, 1, None).into_iter().next().unwrap();
    let solver = SdcaSolver::new(part, LossKind::Square, 0.01, n, 1.0, 1.0, Pcg64::new(3));
    let mut w = WorkerState::new(0, Box::new(solver), 1.0, 128, 0);
    let mut sent = vec![0.0f32; d];
    for round in 1..=5 {
        let msg = w.compute_round();
        assert_eq!(msg.round, round);
        msg.update.add_scaled_into(&mut sent, 1.0);
        assert!(
            w.residual().iter().all(|&x| x == 0.0),
            "round {round}: dense mode left residual mass"
        );
        assert!(w.residual_support().is_empty(), "round {round}");
        w.apply_delta(&DeltaMsg {
            worker: 0,
            server_round: round,
            shutdown: false,
            delta: ModelDelta::Sparse(SparseVec::empty(d)),
        });
    }
    // ledger: with zero residual, Σ sent == (1/λn) Aᵀα exactly up to f32
    let mut expect = vec![0.0f32; d];
    ds.features.t_matvec(w.alpha(), &mut expect);
    let lam_n = 0.01 * n as f64;
    for e in &mut expect {
        *e /= lam_n as f32;
    }
    let max_diff = sent
        .iter()
        .zip(&expect)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-4, "dense-mode conservation violated: {max_diff}");
}

/// LIBSVM write→read round trip: for ANY dataset (random sparsity
/// patterns, empty rows included, ±1 labels) the file format is lossless —
/// f32 values print in shortest-roundtrip form, so the features come back
/// bit-identical, and `d_hint = d` preserves trailing all-zero columns.
#[test]
fn prop_libsvm_write_read_roundtrip() {
    let dir = std::env::temp_dir().join("acpd_libsvm_roundtrip_prop");
    std::fs::create_dir_all(&dir).unwrap();
    forall(
        0x11B5_4321,
        50,
        |rng, sz| {
            let d = 2 + rng.next_below(sz.0 as u32 * 8 + 1) as usize;
            let n = 1 + rng.next_below(sz.0 as u32 + 1) as usize;
            let rows: Vec<(Vec<u32>, Vec<f32>)> = (0..n)
                .map(|_| {
                    let idx = gens::sparse_pattern(rng, Size(sz.0.min(d)), d);
                    let val: Vec<f32> = idx
                        .iter()
                        .map(|_| {
                            let v = rng.next_normal() as f32;
                            if v == 0.0 {
                                1.0
                            } else {
                                v
                            }
                        })
                        .collect();
                    (idx, val)
                })
                .collect();
            let labels: Vec<f32> = (0..n)
                .map(|_| if rng.next_f64() < 0.5 { 1.0 } else { -1.0 })
                .collect();
            let ds = Dataset {
                features: CsrMatrix::from_rows(d, &rows),
                labels,
                name: "prop".into(),
            };
            (ds, rng.next_u64())
        },
        |(ds, tag)| {
            let path = dir.join(format!("case_{tag:016x}.svm"));
            libsvm::write(ds, &path).unwrap();
            let back = libsvm::read(&path, ds.d()).unwrap();
            let _ = std::fs::remove_file(&path);
            back.features == ds.features && back.labels == ds.labels
        },
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn prop_delta_msg_wire_bytes_match_encoding() {
    forall(
        0xB1_0002,
        200,
        |rng, sz| {
            let delta = if rng.next_f64() < 0.5 {
                ModelDelta::Sparse(random_sparse(rng, sz))
            } else {
                let d = 1 + rng.next_below(sz.0 as u32 * 10 + 1) as usize;
                ModelDelta::Dense((0..d).map(|_| rng.next_normal() as f32).collect())
            };
            DeltaMsg {
                worker: rng.next_below(128),
                server_round: rng.next_u64(),
                shutdown: rng.next_f64() < 0.5,
                delta,
            }
        },
        |msg| {
            let buf = msg.encode();
            buf.len() == msg.wire_bytes()
                && matches!(DeltaMsg::decode(&buf), Ok(back) if back == *msg)
        },
    );
}
