//! Protocol-level invariants verified through whole simulated runs.

use acpd::data::synthetic::{self, Preset};
use acpd::data::Dataset;
use acpd::engine::EngineConfig;
use acpd::linalg::dense;
use acpd::network::NetworkModel;

fn ds(seed: u64) -> Dataset {
    let mut spec = Preset::Rcv1Small.spec();
    spec.n = 400;
    spec.d = 800;
    synthetic::generate(&spec, seed)
}

/// w_server must equal (1/λn) Aᵀα at every full barrier when ρ = 1
/// (no filtering): the primal-dual relation, Eq. 5.
#[test]
fn primal_dual_relation_dense() {
    let ds = ds(1);
    let mut cfg = EngineConfig::acpd(4, 2, 5, 1e-2);
    cfg.rho_d = 0; // dense
    cfg.h = 300;
    cfg.outer_rounds = 6;
    let out = acpd::sim::run(&ds, &cfg, &NetworkModel::lan(), 3);
    // residuals must be identically zero in dense mode
    assert!(out.final_residual.iter().all(|&r| r == 0.0));
    let mut w_of_alpha = vec![0.0f32; ds.d()];
    ds.features.t_matvec(&out.final_alpha, &mut w_of_alpha);
    let lam_n = (1e-2 * ds.n() as f64) as f32;
    for w in &mut w_of_alpha {
        *w /= lam_n;
    }
    let max_diff = out
        .final_w
        .iter()
        .zip(&w_of_alpha)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-4, "primal-dual relation broken: {max_diff}");
}

/// With filtering (ρ < 1), the error-feedback residuals account exactly for
/// the difference: w_server + Σ_k γ·residual_k == γ·(1/λn) Aᵀ Δα? — more
/// precisely  w + γ·Σ residual == (1/λn) Aᵀα  (mass conservation).
#[test]
fn mass_conservation_with_filtering() {
    let ds = ds(2);
    let mut cfg = EngineConfig::acpd(4, 2, 5, 1e-2);
    cfg.rho_d = 37; // aggressive filtering
    cfg.h = 300;
    cfg.outer_rounds = 6;
    let out = acpd::sim::run(&ds, &cfg, &NetworkModel::lan(), 5);
    assert!(dense::norm2_sq(&out.final_residual) > 0.0, "expected residual mass");
    let mut w_of_alpha = vec![0.0f32; ds.d()];
    ds.features.t_matvec(&out.final_alpha, &mut w_of_alpha);
    let lam_n = (1e-2 * ds.n() as f64) as f32;
    for w in &mut w_of_alpha {
        *w /= lam_n;
    }
    let gamma = cfg.gamma as f32;
    let max_diff = (0..ds.d())
        .map(|j| (out.final_w[j] + gamma * out.final_residual[j] - w_of_alpha[j]).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-4, "conservation broken: {max_diff}");
}

/// Staleness stays ≤ T−1 for every (B, T) combination, under stragglers.
#[test]
fn staleness_bound_sweep() {
    let ds = ds(3);
    for (b, t) in [(1usize, 2usize), (1, 5), (2, 5), (3, 10), (2, 20)] {
        let mut cfg = EngineConfig::acpd(4, b, t, 1e-2);
        cfg.h = 100;
        cfg.outer_rounds = 8;
        let net = NetworkModel::lan().with_straggler(4, 0, 13.0);
        let out = acpd::sim::run(&ds, &cfg, &net, 7);
        assert!(
            out.stats.max_staleness <= (t - 1) as u64,
            "B={b} T={t}: staleness {} > {}",
            out.stats.max_staleness,
            t - 1
        );
    }
}

/// Fast workers participate more often than the straggler (q_k ordering),
/// yet every worker participates at least once per outer round.
#[test]
fn participation_rates_reflect_straggler() {
    let ds = ds(4);
    let mut cfg = EngineConfig::acpd(4, 2, 10, 1e-2);
    cfg.h = 100;
    cfg.outer_rounds = 12;
    // compute must dominate the 1ms link latency for sigma to matter on
    // this tiny test problem
    let mut net = NetworkModel::lan().with_straggler(4, 2, 8.0);
    net.flop_time = 2e-6;
    let out = acpd::sim::run(&ds, &cfg, &net, 9);
    let q = &out.stats.participation;
    for (k, &qk) in q.iter().enumerate() {
        if k != 2 {
            assert!(
                qk > q[2],
                "worker {k} (q={qk:.3}) should participate more than straggler (q={:.3})",
                q[2]
            );
        }
        // at least the full barriers: >= 1/T of rounds
        assert!(qk >= 1.0 / cfg.period as f64 - 1e-9, "q[{k}] = {qk}");
    }
}

/// Message sizes respect the ρd budget exactly: mean uplink bytes/round/
/// worker ≈ 8·ρd + headers, far below dense 4d.
#[test]
fn byte_budget_respected() {
    let ds = ds(5);
    let rho_d = 50usize;
    let mut cfg = EngineConfig::acpd(4, 4, 5, 1e-2);
    cfg.rho_d = rho_d;
    cfg.h = 200;
    cfg.outer_rounds = 5;
    let out = acpd::sim::run(&ds, &cfg, &NetworkModel::lan(), 11);
    let per_round_per_worker = out.history.mean_bytes_up_per_round() / 4.0;
    let budget = (8 * rho_d + 64) as f64;
    assert!(
        per_round_per_worker <= budget,
        "bytes/round/worker {per_round_per_worker} > budget {budget}"
    );
    // and far below what a dense message would cost (4d payload + headers)
    let dense_wire = (4 * ds.d() + 32) as f64;
    assert!(
        per_round_per_worker < dense_wire / 5.0,
        "{per_round_per_worker} not << dense {dense_wire}"
    );
}

/// Ablation of the paper's §III-B2 practical variant: with error feedback
/// the filtered-out mass is recovered in later rounds; dropping it instead
/// loses optimization progress at aggressive ρ.
#[test]
fn error_feedback_beats_dropping() {
    let ds = ds(8);
    let mut with_fb = EngineConfig::acpd(4, 4, 5, 1e-2);
    with_fb.rho_d = 20; // very aggressive compression
    with_fb.h = 400;
    with_fb.outer_rounds = 20;
    let mut without_fb = with_fb.clone();
    without_fb.error_feedback = false;
    let a = acpd::sim::run(&ds, &with_fb, &NetworkModel::lan(), 3);
    let b = acpd::sim::run(&ds, &without_fb, &NetworkModel::lan(), 3);
    assert!(
        a.history.last_gap() < b.history.last_gap(),
        "feedback {:.3e} should beat dropping {:.3e}",
        a.history.last_gap(),
        b.history.last_gap()
    );
    // dropping leaves no residual by construction
    assert!(b.final_residual.iter().all(|&r| r == 0.0));
}

/// All shipped configs must parse and validate — experiment configs as
/// `ExperimentConfig` (engines checked against their preset's n), sweep
/// configs ([sweep]-only files) as `SweepSpec` with valid per-cell engines.
#[test]
fn shipped_configs_are_valid() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");
    let mut seen = 0;
    for entry in std::fs::read_dir(&dir).expect("configs/ exists") {
        let path = entry.unwrap().path();
        if !path.extension().map(|e| e == "toml").unwrap_or(false) {
            continue;
        }
        seen += 1;
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = acpd::config::toml::Document::parse(&text)
            .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
        let is_sweep = doc.sections.contains_key("sweep")
            && !doc.sections.contains_key("data")
            && !doc.sections.contains_key("algo");
        if is_sweep {
            let spec = acpd::sweep::SweepSpec::from_toml(&text)
                .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
            let cells = spec.cells();
            assert!(!cells.is_empty(), "{}: empty sweep grid", path.display());
            for cell in &cells {
                let n = if spec.n_override > 0 {
                    spec.n_override
                } else {
                    match &cell.source {
                        acpd::data::DatasetSource::Preset(p) => p.spec().n,
                        // file-backed sources can't be sized statically;
                        // shipped configs only reference presets anyway
                        acpd::data::DatasetSource::Libsvm { .. } => 1_000_000,
                    }
                };
                spec.engine_for(cell)
                    .validate(n)
                    .unwrap_or_else(|e| panic!("{} cell {}: {e:#}", path.display(), cell.index));
            }
        } else {
            let cfg = acpd::config::ExperimentConfig::from_file(&path)
                .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
            // engine must validate against its own preset's n
            let n = match &cfg.data {
                acpd::config::schema::DataSource::Preset(p) => p.spec().n,
                acpd::config::schema::DataSource::Libsvm { .. } => 1_000_000,
            };
            cfg.engine.validate(n).unwrap();
        }
    }
    assert!(seen >= 3, "expected >= 3 shipped configs, found {seen}");
}

/// Determinism across identical runs, and sensitivity to the seed.
#[test]
fn deterministic_given_seed() {
    let ds = ds(6);
    let mut cfg = EngineConfig::acpd(4, 2, 5, 1e-2);
    cfg.h = 150;
    cfg.outer_rounds = 4;
    let a = acpd::sim::run(&ds, &cfg, &NetworkModel::lan(), 42);
    let b = acpd::sim::run(&ds, &cfg, &NetworkModel::lan(), 42);
    assert_eq!(a.final_w, b.final_w);
    assert_eq!(a.stats.bytes_up, b.stats.bytes_up);
    let c = acpd::sim::run(&ds, &cfg, &NetworkModel::lan(), 43);
    assert_ne!(a.final_w, c.final_w);
}

/// The generalization sanity check: the trained model actually classifies
/// the synthetic concept well above chance.
#[test]
fn trained_model_classifies() {
    let ds = ds(7);
    let mut cfg = EngineConfig::acpd(4, 2, 10, 1e-2);
    cfg.h = 600;
    cfg.outer_rounds = 20;
    cfg.target_gap = 1e-5;
    let out = acpd::sim::run(&ds, &cfg, &NetworkModel::lan(), 13);
    let mut correct = 0usize;
    for i in 0..ds.n() {
        let z = ds.features.row_dot(i, &out.final_w);
        if (z >= 0.0) == (ds.labels[i] > 0.0) {
            correct += 1;
        }
    }
    let acc = correct as f64 / ds.n() as f64;
    assert!(acc > 0.75, "train accuracy only {acc:.3}");
}
