"""L1 correctness: every Pallas kernel vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes/seeds/hyperparameters; assert_allclose against ref.
This is the CORE correctness signal for the compute layer.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import gap, ref, sdca, topk

SET = dict(deadline=None, max_examples=15, print_blob=True)


def make_problem(seed, n, d, h, density=1.0):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n, d)).astype(np.float32)
    if density < 1.0:
        A *= (rng.random((n, d)) < density).astype(np.float32)
    # paper Assumption 1: ||x_i|| <= 1
    norms = np.maximum(np.linalg.norm(A, axis=1, keepdims=True), 1e-6)
    A = A / norms
    y = rng.choice([-1.0, 1.0], n).astype(np.float32)
    alpha = (rng.normal(size=n) * 0.1).astype(np.float32)
    w = (rng.normal(size=d) * 0.05).astype(np.float32)
    idx = rng.integers(0, n, h).astype(np.int32)
    sqn = (A * A).sum(1).astype(np.float32)
    return A, y, alpha, w, idx, sqn


# ---------------------------------------------------------------- SDCA epoch


@settings(**SET)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.sampled_from([8, 32, 128, 256]),
    d=st.sampled_from([4, 64, 128]),
    h=st.sampled_from([1, 17, 100]),
    lam=st.sampled_from([1e-4, 1e-2, 1.0]),
    sig=st.sampled_from([0.5, 1.0, 4.0]),
)
def test_sdca_epoch_matches_ref(seed, n, d, h, lam, sig):
    A, y, alpha, w, idx, sqn = make_problem(seed, n, d, h)
    lam_n = lam * n * 4  # pretend global n = 4 * local n
    a1, dw1 = ref.sdca_epoch(A, y, alpha, w, idx, sqn, lam_n, sig)
    a2, dw2 = sdca.sdca_epoch(A, y, alpha, w, idx, sqn, lam_n, sig)
    assert_allclose(np.asarray(a1), np.asarray(a2), rtol=1e-5, atol=1e-5)
    assert_allclose(np.asarray(dw1), np.asarray(dw2), rtol=1e-5, atol=1e-5)


def test_sdca_step_is_1d_argmax():
    """The closed-form coordinate step exactly maximizes the 1-D subproblem."""
    A, y, alpha, w, idx, sqn = make_problem(7, 16, 8, 1)
    lam_n, sig = 16.0, 2.0
    i = int(idx[0])
    a1, _ = ref.sdca_epoch(A, y, alpha, w, idx[:1], sqn, lam_n, sig)
    delta_star = float(a1[i] - alpha[i])

    def obj(delta):
        # 1-D restriction of G_k^{sigma'} (up to constants), in f64
        a = np.float64(alpha[i]) + delta
        return (a * np.float64(y[i]) - a * a / 2.0) - np.dot(
            w.astype(np.float64), A[i].astype(np.float64)
        ) * delta - (sig / (2.0 * lam_n)) * np.float64(sqn[i]) * delta * delta

    grid = np.float64(delta_star) + np.linspace(-0.5, 0.5, 1001)
    assert obj(np.float64(delta_star)) >= obj(grid).max() - 1e-7


@settings(**SET)
@given(seed=st.integers(0, 2**31 - 1))
def test_sdca_epoch_increases_local_objective(seed):
    """H steps of coordinate ascent never decrease the local dual objective."""
    A, y, alpha, w, idx, sqn = make_problem(seed, 64, 32, 200)
    lam_n, sig = 64.0, 2.0
    a1, dw = ref.sdca_epoch(A, y, alpha, w, idx, sqn, lam_n, sig)
    dalpha = np.asarray(a1) - alpha

    def G(da):
        a = alpha + da
        u = (A.T @ da) / lam_n  # (1/(lam n)) A^T da
        conj = np.sum(a * y - a * a / 2.0)
        return conj - lam_n * np.dot(w, u) - sig * lam_n / 2.0 * np.dot(u, u)

    assert G(dalpha) >= G(np.zeros_like(dalpha)) - 1e-4


def test_sdca_delta_w_consistency():
    """delta_w returned by the kernel equals (1/lam_n) A^T (alpha' - alpha)."""
    A, y, alpha, w, idx, sqn = make_problem(3, 128, 64, 300)
    lam_n, sig = 512.0, 3.0
    a1, dw = sdca.sdca_epoch(A, y, alpha, w, idx, sqn, lam_n, sig)
    expect = A.T @ (np.asarray(a1) - alpha) / lam_n
    assert_allclose(np.asarray(dw), expect, rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------- top-k


@settings(**SET)
@given(
    seed=st.integers(0, 2**31 - 1),
    d=st.sampled_from([8, 100, 512, 1000]),
    frac=st.sampled_from([0.01, 0.1, 0.5, 1.0]),
)
def test_topk_filter_properties(seed, d, frac):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=d).astype(np.float32)
    k = max(1, int(frac * d))
    filt, resid, c = topk.topk_filter(w, k)
    filt, resid = np.asarray(filt), np.asarray(resid)
    # mass conservation (error feedback invariant)
    assert_allclose(filt + resid, w, rtol=0, atol=0)
    # disjoint supports
    assert not np.any((filt != 0) & (resid != 0))
    # bisection support within k (+ slack only from exact magnitude ties)
    support = int((filt != 0).sum())
    assert support <= k + int((np.abs(w) == float(c)).sum())
    # everything kept dominates everything dropped
    if support and support < d:
        assert np.abs(filt[filt != 0]).min() >= np.abs(resid[resid != 0]).max() - 1e-7


@settings(**SET)
@given(seed=st.integers(0, 2**31 - 1), d=st.sampled_from([16, 257, 1024]))
def test_topk_bisect_matches_exact_support(seed, d):
    """Bisection threshold keeps the same entries as the exact sort oracle
    (distinct magnitudes almost surely with continuous data)."""
    rng = np.random.default_rng(seed)
    w = rng.normal(size=d).astype(np.float32)
    k = d // 4 + 1
    f_exact, _, _ = ref.topk_filter(w, k)
    f_bis, _, _ = topk.topk_filter(w, k)
    assert (np.asarray(f_exact) != 0).sum() == (np.asarray(f_bis) != 0).sum()
    assert_allclose(np.asarray(f_exact), np.asarray(f_bis), atol=0)


def test_topk_rho_one_is_identity():
    """rho = 1 (no compression ablation) passes everything through."""
    rng = np.random.default_rng(0)
    w = rng.normal(size=300).astype(np.float32)
    filt, resid, _ = topk.topk_filter(w, 300)
    assert_allclose(np.asarray(filt), w, atol=0)
    assert np.all(np.asarray(resid) == 0)


def test_topk_k_dynamic_is_runtime_input():
    """Same jitted filter works for different k without recompilation."""
    rng = np.random.default_rng(1)
    w = rng.normal(size=256).astype(np.float32)
    for k in (1, 10, 128, 256):
        filt, _, _ = topk.topk_filter(w, k)
        assert (np.asarray(filt) != 0).sum() <= k


# ---------------------------------------------------------------- gap pieces


@settings(**SET)
@given(
    seed=st.integers(0, 2**31 - 1),
    blocks=st.sampled_from([1, 2, 5]),
    d=st.sampled_from([8, 128, 300]),
)
def test_gap_pieces_match_ref(seed, blocks, d):
    n = 128 * blocks  # gap kernel tiles rows in 128-blocks
    A, y, alpha, w, _, _ = make_problem(seed, n, d, 1)
    l1, c1, v1 = ref.objective_pieces(A, y, alpha, w)
    l2, c2, v2 = gap.objective_pieces(A, y, alpha, w)
    assert_allclose(float(l1), float(l2), rtol=1e-4)
    assert_allclose(float(c1), float(c2), rtol=1e-4, atol=1e-5)
    assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-4, atol=1e-4)


@settings(**SET)
@given(seed=st.integers(0, 2**31 - 1))
def test_duality_gap_nonnegative(seed):
    """P(w(alpha)) - D(alpha) >= 0 at the primal-dual-consistent point."""
    A, y, alpha, w, _, _ = make_problem(seed, 128, 64, 1)
    lam = 1e-2
    n = A.shape[0]
    w_of_alpha = A.T @ alpha / (lam * n)
    p, d_, g = ref.primal_dual(A, y, alpha, w_of_alpha, lam)
    assert float(g) >= -1e-6


def test_gap_zero_at_optimum():
    """Closed-form ridge optimum has (near-)zero duality gap."""
    A, y, _, _, _, _ = make_problem(11, 128, 32, 1)
    lam, n = 0.1, 128
    # alpha* solves (I + X X^T/(lam n)) alpha = y  for square loss dual
    Kmat = A @ A.T / (lam * n) + np.eye(n)
    alpha_star = np.linalg.solve(Kmat, y).astype(np.float32)
    w_star = A.T @ alpha_star / (lam * n)
    _, _, g = ref.primal_dual(A, y, alpha_star, w_star, lam)
    assert abs(float(g)) < 1e-5
