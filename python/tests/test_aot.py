"""AOT pipeline: lowering produces loadable HLO text + a sane manifest."""

import os
import subprocess
import sys

import pytest

PY_DIR = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
         "--variants", "test"],
        cwd=PY_DIR,
        check=True,
        capture_output=True,
    )
    return out


def test_manifest_entries(artifacts):
    lines = (artifacts / "manifest.txt").read_text().strip().splitlines()
    assert lines[0].startswith("#")
    entries = [l for l in lines if l.startswith("entry ")]
    names = set()
    for line in entries:
        kv = dict(tok.split("=", 1) for tok in line.split()[1:])
        assert {"name", "variant", "file", "nk", "d", "h", "nin", "nout"} <= set(kv)
        assert (artifacts / kv["file"]).exists()
        names.add(kv["name"])
    assert names == {"local_round", "objectives", "sdca_epoch", "topk_filter"}


def test_hlo_text_is_parseable_hlo(artifacts):
    for f in artifacts.glob("*.hlo.txt"):
        text = f.read_text()
        assert "HloModule" in text, f.name
        assert "ENTRY" in text, f.name
        # interpret-mode pallas must NOT leave mosaic custom-calls behind
        assert "tpu_custom_call" not in text, f.name
        assert "mosaic" not in text.lower(), f.name


def test_local_round_hlo_shapes(artifacts):
    text = (artifacts / "local_round_test.hlo.txt").read_text()
    # 8 parameters with the manifest shapes
    assert "f32[256,128]" in text  # A
    assert "s32[256]" in text      # idx (h=256)
    assert "f32[4]" in text        # scalars


def test_roundtrip_reparse(artifacts):
    """Parse the HLO text back through XLA's own parser — validates the text
    is a complete module (ids, shapes, computations).  Full load+EXECUTE
    round-trip happens on the rust side (rust/tests/runtime_hlo.rs), which is
    the consumer that matters."""
    from jax._src.lib import xla_client as xc

    for f in artifacts.glob("*.hlo.txt"):
        m = xc._xla.hlo_module_from_text(f.read_text())
        reprinted = m.to_string()
        assert "ENTRY" in reprinted, f.name
        # serializes to a proto without raising => structurally complete
        assert len(m.as_serialized_hlo_module_proto()) > 100, f.name
