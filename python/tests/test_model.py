"""L2 correctness: model.local_round / model.objectives semantics.

local_round must equal the hand-composed pipeline: centring on
w_k + gamma*resid, SDCA epoch, error-feedback carry-in, top-k split.
"""

import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref

SET = dict(deadline=None, max_examples=10, print_blob=True)


def make_round_inputs(seed, n=128, d=64, h=100):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n, d)).astype(np.float32)
    A /= np.maximum(np.linalg.norm(A, axis=1, keepdims=True), 1e-6)
    y = rng.choice([-1.0, 1.0], n).astype(np.float32)
    alpha = (rng.normal(size=n) * 0.1).astype(np.float32)
    w_k = (rng.normal(size=d) * 0.05).astype(np.float32)
    resid = (rng.normal(size=d) * 0.01).astype(np.float32)
    idx = rng.integers(0, n, h).astype(np.int32)
    sqn = (A * A).sum(1).astype(np.float32)
    return A, y, alpha, w_k, resid, idx, sqn


@settings(**SET)
@given(
    seed=st.integers(0, 2**31 - 1),
    gamma=st.sampled_from([0.25, 0.5, 1.0]),
    k=st.sampled_from([4, 16, 64]),
)
def test_local_round_composition(seed, gamma, k):
    A, y, alpha, w_k, resid, idx, sqn = make_round_inputs(seed)
    lam_n, sig = 512.0, gamma * 2
    scalars = np.array([lam_n, sig, gamma, k], np.float32)

    a1, filt, resid_out, c = model.local_round(
        A, y, alpha, w_k, resid, idx, sqn, scalars
    )
    # hand-composed reference
    w_eff = w_k + gamma * resid
    a_full, dw = ref.sdca_epoch(A, y, alpha, w_eff, idx, sqn, lam_n, sig)
    a_ref = alpha + gamma * (np.asarray(a_full) - alpha)  # line 5 scaling
    dw_total = resid + np.asarray(dw)

    assert_allclose(np.asarray(a1), np.asarray(a_ref), rtol=1e-5, atol=1e-5)
    # conservation: filtered + residual == resid_in + epoch delta_w
    assert_allclose(
        np.asarray(filt) + np.asarray(resid_out), dw_total, rtol=1e-5, atol=1e-6
    )
    assert (np.asarray(filt) != 0).sum() <= k + 1


@settings(**SET)
@given(seed=st.integers(0, 2**31 - 1))
def test_local_round_progress(seed):
    """Repeated local rounds (single worker, K=1 semantics) drive the duality
    gap down — the end-to-end sanity of the compute layer."""
    A, y, alpha, w_k, _, _, sqn = make_round_inputs(seed, n=128, d=32, h=256)
    lam = 0.05
    n = A.shape[0]
    lam_n = lam * n
    gamma, B = 1.0, 1
    scalars = np.array([lam_n, gamma * B, gamma, 32], np.float32)
    alpha = np.zeros(n, np.float32)
    w = np.zeros(32, np.float32)
    resid = np.zeros(32, np.float32)
    rng = np.random.default_rng(seed)

    gaps = []
    for _ in range(6):
        idx = rng.integers(0, n, 256).astype(np.int32)
        alpha_j, filt, resid, _ = model.local_round(
            A, y, alpha, w, resid, idx, sqn, scalars
        )
        alpha = np.asarray(alpha_j)
        w = w + gamma * np.asarray(filt)  # server applies F(dw)
        _, _, g = ref.primal_dual(A, y, alpha, w + resid, lam)
        gaps.append(float(g))
    assert gaps[-1] < gaps[0] * 0.5


def test_objectives_shapes_and_values():
    A, y, alpha, w_k, _, _, _ = make_round_inputs(0, n=256, d=64)
    loss, conj, v = model.objectives(A, y, alpha, w_k)
    assert np.asarray(loss).shape == (1,)
    assert np.asarray(conj).shape == (1,)
    assert np.asarray(v).shape == (64,)
    l_ref, c_ref, v_ref = ref.objective_pieces(A, y, alpha, w_k)
    assert_allclose(float(np.asarray(loss)[0]), float(l_ref), rtol=1e-4)
    assert_allclose(float(np.asarray(conj)[0]), float(c_ref), rtol=1e-4, atol=1e-5)
    assert_allclose(np.asarray(v), np.asarray(v_ref), rtol=1e-4, atol=1e-4)


def test_standalone_entries_match_composed():
    A, y, alpha, w_k, resid, idx, sqn = make_round_inputs(5)
    lam_n, sig = 512.0, 2.0
    a1, dw1 = model.sdca_epoch(
        A, y, alpha, w_k, idx, sqn, np.array([lam_n, sig], np.float32)
    )
    a2, dw2 = ref.sdca_epoch(A, y, alpha, w_k, idx, sqn, lam_n, sig)
    assert_allclose(np.asarray(a1), np.asarray(a2), rtol=1e-5, atol=1e-5)
    f1, r1, c1 = model.topk_filter(dw1, np.array([8.0], np.float32))
    assert (np.asarray(f1) != 0).sum() <= 8
    assert_allclose(np.asarray(f1) + np.asarray(r1), np.asarray(dw1), atol=0)
