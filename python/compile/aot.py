"""AOT: lower the L2 graphs to HLO *text* artifacts for the rust runtime.

Interchange is HLO text, NOT serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which the image's xla_extension 0.5.1
(what the published ``xla`` crate binds) rejects; the text parser reassigns
ids and round-trips cleanly.  See /opt/xla-example/README.md.

Usage:
    python -m compile.aot --out-dir ../artifacts [--variants test,e2e,...]

Outputs <out-dir>/<entry>_<variant>.hlo.txt plus a line-based manifest.txt
the rust side parses:

    entry name=local_round variant=e2e file=local_round_e2e.hlo.txt \
          nk=2048 d=1024 h=2048

Shape variants deliberately use 128-multiples (TPU tiling; see DESIGN.md
§Hardware-Adaptation).  The scalar-vector calling conventions are documented
in model.py and mirrored by rust/src/runtime/.
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (name, n_k, d, h).  n_k multiples of 128 (gap kernel tiling), d multiples
# of 128 (VPU lanes).  "test" is sized for fast pytest/cargo-test cycles;
# "quickstart" for the quickstart example; "e2e" for the end-to-end driver
# (n=8192 over K=4 workers => n_k=2048).
VARIANTS = {
    "test": dict(nk=256, d=128, h=256),
    "quickstart": dict(nk=1024, d=512, h=1024),
    "e2e": dict(nk=2048, d=1024, h=2048),
}

F32 = jnp.float32
I32 = jnp.int32


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def entry_signatures(nk: int, d: int, h: int):
    """Input specs per entry, in positional order (the PJRT call order)."""
    return {
        "local_round": [
            _spec((nk, d)),      # A
            _spec((nk,)),        # y
            _spec((nk,)),        # alpha
            _spec((d,)),         # w_k
            _spec((d,)),         # resid
            _spec((h,), I32),    # idx
            _spec((nk,)),        # sqnorms
            _spec((4,)),         # scalars [lam_n, sigma', gamma, k]
        ],
        "objectives": [
            _spec((nk, d)),      # A
            _spec((nk,)),        # y
            _spec((nk,)),        # alpha
            _spec((d,)),         # w
        ],
        "sdca_epoch": [
            _spec((nk, d)),
            _spec((nk,)),
            _spec((nk,)),
            _spec((d,)),
            _spec((h,), I32),
            _spec((nk,)),
            _spec((2,)),         # [lam_n, sigma']
        ],
        "topk_filter": [
            _spec((d,)),
            _spec((1,)),         # [k]
        ],
    }


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True: the rust
    side unwraps one tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(name: str, shapes: dict, out_dir: str, manifest: list):
    nk, d, h = shapes["nk"], shapes["d"], shapes["h"]
    sigs = entry_signatures(nk, d, h)
    for entry, specs in sigs.items():
        fn = getattr(model, entry)
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{entry}_{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        nouts = {
            "local_round": 4,
            "objectives": 3,
            "sdca_epoch": 2,
            "topk_filter": 3,
        }[entry]
        manifest.append(
            f"entry name={entry} variant={name} file={fname} "
            f"nk={nk} d={d} h={h} nin={len(specs)} nout={nouts}"
        )
        print(f"  {fname}: {len(text)} chars")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--variants", default=",".join(VARIANTS))
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = ["# acpd artifact manifest v1"]
    for name in args.variants.split(","):
        name = name.strip()
        if not name:
            continue
        print(f"variant {name}: {VARIANTS[name]}")
        lower_variant(name, VARIANTS[name], args.out_dir, manifest)
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {len(manifest) - 1} entries to {args.out_dir}/manifest.txt")


if __name__ == "__main__":
    main()
