"""L2: the jitted compute graphs that the rust coordinator executes via PJRT.

Each public function here is one AOT artifact (per shape variant).  They are
composed from the L1 Pallas kernels so the kernel lowers into the same HLO
module; the rust hot path performs exactly ONE PJRT execute per worker round
(``local_round``) and one per gap evaluation (``objectives``).

Calling conventions (all f32 unless noted, shapes per manifest variant):

``local_round(A, y, alpha, w_k, resid, idx:i32, sqnorms, scalars)``
    scalars = [lam_n, sigma_prime, gamma, k]
    1. w_eff      = w_k + gamma * resid          (Algorithm 2 line 4 centring)
    2. alpha', dw = sdca_epoch(...) for H steps  (L1 kernel)
    3. dw_total   = resid + dw                   (error feedback carry-in)
    4. F, resid'  = top-k filter(dw_total)       (L1 kernel + bisection)
    returns (alpha', F(dw), resid', threshold[1])

``objectives(A, y, alpha, w)`` -> (loss_sum[1], conj_sum[1], v[d])
    per-partition duality-gap pieces (L1 gap kernel).

``sdca_epoch`` / ``topk_filter`` are also exported standalone for tests and
microbenches.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import gap as gap_k
from .kernels import sdca as sdca_k
from .kernels import topk as topk_k


def local_round(A, y, alpha, w_k, resid, idx, sqnorms, scalars):
    """One full bandwidth-efficient worker round (Algorithm 2 lines 3-12)."""
    lam_n = scalars[0]
    sigma_prime = scalars[1]
    gamma = scalars[2]
    k = scalars[3]
    w_eff = w_k + gamma * resid
    alpha_new, dw = sdca_k.sdca_epoch(
        A, y, alpha, w_eff, idx, sqnorms, lam_n, sigma_prime
    )
    # Algorithm 2 line 5: the retained dual state is alpha + gamma*delta_alpha
    # (delta_w stays unscaled; the server applies its own gamma on aggregation,
    # which keeps w = (1/lam_n) A^T alpha globally).
    alpha_ret = alpha + gamma * (alpha_new - alpha)
    dw_total = resid + dw
    filt, resid_out, c = topk_k.topk_filter(dw_total, k)
    return alpha_ret, filt, resid_out, jnp.reshape(c, (1,))


def objectives(A, y, alpha, w):
    """Per-partition duality-gap pieces; see kernels.gap."""
    loss_sum, conj_sum, v = gap_k.objective_pieces(A, y, alpha, w)
    return jnp.reshape(loss_sum, (1,)), jnp.reshape(conj_sum, (1,)), v


def sdca_epoch(A, y, alpha, w_eff, idx, sqnorms, scalars):
    """Standalone SDCA epoch; scalars = [lam_n, sigma_prime]."""
    return sdca_k.sdca_epoch(A, y, alpha, w_eff, idx, sqnorms, scalars[0], scalars[1])


def topk_filter(delta_w, scalars):
    """Standalone filter; scalars = [k]."""
    filt, resid, c = topk_k.topk_filter(delta_w, scalars[0])
    return filt, resid, jnp.reshape(c, (1,))
