"""Build-time (compile-path) python package for the ACPD reproduction.

Nothing in here is imported at runtime: ``aot.py`` lowers the jitted L2
functions in ``model.py`` (which call the L1 Pallas kernels) to HLO *text*
once, and the rust coordinator loads the artifacts via the PJRT C API.
"""
