"""Pure-jnp reference oracle for the L1 Pallas kernels.

Every Pallas kernel in this package has an exact (up to float round-off)
counterpart here, written with plain ``jax.numpy`` so that pytest can assert
``kernel(x) == ref(x)``.  These are also the semantics documents: if a kernel
and its ref disagree, the ref wins.

All functions are shape-polymorphic and jittable.  The math follows the paper
(Huo & Huang 2019), ridge regression instantiation (Eq. 25):

  primal   P(w)    = (1/n) sum_i 0.5 (w.x_i - y_i)^2 + (lam/2) ||w||^2
  dual     D(alpha)= (1/n) sum_i (alpha_i y_i - alpha_i^2/2)
                     - (lam/2) || (1/(lam n)) A^T alpha ||^2
  SDCA coordinate step on the local subproblem G_k^{sigma'} (Eq. 8):
      delta_i = (y_i - alpha_i - x_i.(w_eff + u)) / (1 + sigma' ||x_i||^2/(lam n))
      u      += (sigma'/(lam n)) * delta_i * x_i
  where u tracks sigma' * (1/(lam n)) A_[k]^T delta_alpha over the epoch.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# SDCA epoch (Algorithm 2, line 4) — square loss, H sequential steps
# ---------------------------------------------------------------------------


def sdca_epoch(A, y, alpha, w_eff, idx, sqnorms, lam_n, sigma_prime):
    """Run ``len(idx)`` SDCA coordinate-ascent steps on the local subproblem.

    Args:
      A:        (n_k, d) dense local data partition (rows are samples).
      y:        (n_k,) labels.
      alpha:    (n_k,) local dual variables at epoch start.
      w_eff:    (d,) effective primal iterate the subproblem is centred on
                (``w_k + gamma * delta_w_k`` in Algorithm 2).
      idx:      (H,) int32 coordinate schedule (sampled by the caller; shared
                with the rust path so both solvers walk the same stream).
      sqnorms:  (n_k,) precomputed ||x_i||^2.
      lam_n:    scalar, lambda * n  (n = GLOBAL sample count).
      sigma_prime: scalar, subproblem difficulty sigma' = gamma * B.

    Returns:
      (alpha_new, delta_w): updated duals and the primal update
      ``delta_w = (1/(lam n)) A^T (alpha_new - alpha)``.
    """
    A = jnp.asarray(A)
    y = jnp.asarray(y)
    alpha = jnp.asarray(alpha)
    w_eff = jnp.asarray(w_eff)
    idx = jnp.asarray(idx)
    sqnorms = jnp.asarray(sqnorms)
    scale = sigma_prime / lam_n

    def body(_h, carry):
        alpha_c, u = carry
        i = idx[_h]
        x = A[i]
        z = jnp.dot(x, w_eff + u)
        denom = 1.0 + sigma_prime * sqnorms[i] / lam_n
        delta = (y[i] - alpha_c[i] - z) / denom
        alpha_c = alpha_c.at[i].add(delta)
        u = u + scale * delta * x
        return alpha_c, u

    alpha_new, u = jax.lax.fori_loop(
        0, idx.shape[0], body, (alpha, jnp.zeros_like(w_eff))
    )
    # u = sigma'/(lam n) * A^T dalpha  =>  delta_w = u / sigma'
    delta_w = u / sigma_prime
    return alpha_new, delta_w


# ---------------------------------------------------------------------------
# Top-(rho d) magnitude filter (Algorithm 2, lines 7-9) — exact, sort-based
# ---------------------------------------------------------------------------


def topk_threshold_exact(delta_w, k):
    """Exact k-th largest magnitude of ``delta_w`` (static k), via sort."""
    mags = jnp.sort(jnp.abs(delta_w))[::-1]
    k = max(1, min(int(k), delta_w.shape[0]))
    return mags[k - 1]


def topk_filter(delta_w, k):
    """Split ``delta_w`` into (filtered F(dw), residual) with exact top-k mask.

    mask M(i) = |dw_i| >= c  where c is the k-th largest magnitude.  Ties can
    push the support above k (matches the paper's definition of M_k).
    ``filtered + residual == delta_w`` exactly.
    """
    c = topk_threshold_exact(delta_w, k)
    mask = jnp.abs(delta_w) >= c
    filtered = jnp.where(mask, delta_w, 0.0)
    return filtered, delta_w - filtered, c


def topk_threshold_bisect(delta_w, k, iters=48):
    """Bisection threshold with *dynamic* k: smallest representable c such
    that count(|dw| >= c) <= k (up to bisection resolution).  This is the
    XLA-path algorithm; exact selection is quickselect on the rust path."""
    mags = jnp.abs(delta_w)
    lo = jnp.asarray(0.0, delta_w.dtype)
    hi = jnp.max(mags) + jnp.asarray(1e-12, delta_w.dtype)

    def body(_i, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum(mags >= mid)
        too_many = cnt > k
        return jnp.where(too_many, mid, lo), jnp.where(too_many, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return hi


# ---------------------------------------------------------------------------
# Objectives (duality-gap pieces) — per-partition contributions
# ---------------------------------------------------------------------------


def objective_pieces(A, y, alpha, w):
    """Per-partition contributions to P(w) and D(alpha) for the square loss.

    Returns (loss_sum, conj_sum, v) where
      loss_sum = sum_i 0.5 (x_i.w - y_i)^2         (primal loss part)
      conj_sum = sum_i (alpha_i y_i - alpha_i^2/2)  (dual -phi^*(-alpha) part)
      v        = A^T alpha                          (d,) for ||w(alpha)||^2

    The driver combines partitions:
      P = loss_sum_tot/n + lam/2 ||w||^2
      D = conj_sum_tot/n - lam/2 || v_tot/(lam n) ||^2
    """
    z = A @ w
    loss_sum = 0.5 * jnp.sum((z - y) ** 2)
    conj_sum = jnp.sum(alpha * y - 0.5 * alpha**2)
    v = A.T @ alpha
    return loss_sum, conj_sum, v


def primal_dual(A, y, alpha, w, lam):
    """Full-dataset primal, dual and gap (single partition convenience)."""
    n = A.shape[0]
    loss_sum, conj_sum, v = objective_pieces(A, y, alpha, w)
    primal = loss_sum / n + 0.5 * lam * jnp.dot(w, w)
    wa = v / (lam * n)
    dual = conj_sum / n - 0.5 * lam * jnp.dot(wa, wa)
    return primal, dual, primal - dual
