"""L1 Pallas kernels for ACPD (ridge-regression instantiation).

- ``sdca``: H-step local SDCA epoch (Algorithm 2 line 4) — the hot spot.
- ``topk``: bandwidth filter F + residual split (Algorithm 2 lines 7-12).
- ``gap``: duality-gap pieces (loss/conjugate sums + A^T alpha) in one pass.
- ``ref``: pure-jnp oracle for all of the above.

All kernels run under ``interpret=True`` so they lower to plain HLO the CPU
PJRT client can execute; see DESIGN.md §Hardware-Adaptation.
"""

from . import gap, ref, sdca, topk  # noqa: F401
