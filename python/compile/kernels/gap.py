"""L1 Pallas kernel: duality-gap pieces in one pass over the partition.

Computes, for a dense local partition A (n_k, d):

    loss_sum = sum_i 0.5 (x_i.w - y_i)^2        (square-loss primal part)
    conj_sum = sum_i (alpha_i y_i - alpha_i^2/2)
    v        = A^T alpha                         (d,)

in a single HBM read of A.  TPU mapping (DESIGN.md §Hardware-Adaptation):
the grid tiles the sample axis in TILE_N=128 row blocks; each program does an
MXU-shaped (128, d) x (d,) matvec for z = A_blk.w and a (128,)x(128, d)
vector-matrix product for the v accumulation, then fuses the per-sample loss
math into the same pass.  Scalar partial sums land in a per-program slot of a
(grid,)-shaped output (no cross-program races); v accumulates into a single
(d,) block, initialised by program 0 — the canonical sequential-grid
accumulation pattern on TPU.

VMEM per program: A block 128*d*4 (d=8192 -> 4 MiB) + 3 d-vectors.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 128


def _gap_kernel(y_ref, alpha_ref, w_ref, a_ref, loss_ref, conj_ref, v_ref):
    pid = pl.program_id(0)

    @pl.when(pid == 0)
    def _init():
        v_ref[...] = jnp.zeros_like(v_ref)

    a_blk = a_ref[...]          # (TILE_N, d)
    alpha_blk = alpha_ref[...]  # (TILE_N,)
    z = a_blk @ w_ref[...]      # MXU-shaped matvec
    r = z - y_ref[...]
    loss_ref[0] = 0.5 * jnp.sum(r * r)
    conj_ref[0] = jnp.sum(alpha_blk * y_ref[...] - 0.5 * alpha_blk * alpha_blk)
    v_ref[...] = v_ref[...] + alpha_blk @ a_blk


@jax.jit
def objective_pieces(A, y, alpha, w):
    """Pallas-backed twin of ``ref.objective_pieces``.

    Requires n_k to be a multiple of TILE_N (the AOT shape variants are);
    callers with ragged n_k zero-pad rows (zero rows contribute y=0, alpha=0
    => loss 0.5*z^2 with z=0, i.e. nothing).
    """
    n_k, d = A.shape
    assert n_k % TILE_N == 0, f"n_k={n_k} must be a multiple of {TILE_N}"
    grid = n_k // TILE_N
    loss_p, conj_p, v = pl.pallas_call(
        _gap_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((TILE_N,), lambda i: (i,)),       # y
            pl.BlockSpec((TILE_N,), lambda i: (i,)),       # alpha
            pl.BlockSpec((d,), lambda i: (0,)),            # w (replicated)
            pl.BlockSpec((TILE_N, d), lambda i: (i, 0)),   # A row-tiles
        ],
        out_specs=(
            pl.BlockSpec((1,), lambda i: (i,)),            # loss partials
            pl.BlockSpec((1,), lambda i: (i,)),            # conj partials
            pl.BlockSpec((d,), lambda i: (0,)),            # v (accumulated)
        ),
        out_shape=(
            jax.ShapeDtypeStruct((grid,), jnp.float32),
            jax.ShapeDtypeStruct((grid,), jnp.float32),
            jax.ShapeDtypeStruct((d,), jnp.float32),
        ),
        interpret=True,
    )(y, alpha, w, A)
    return jnp.sum(loss_p), jnp.sum(conj_p), v
