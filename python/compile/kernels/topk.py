"""L1 Pallas kernel: the bandwidth filter F (Algorithm 2, lines 7-12).

The filter keeps the top-(rho*d) entries of |delta_w| and leaves the rest
behind as a local residual (practical variant of lines 10-12, i.e. error
feedback): ``F(dw) = dw * M``, ``residual = dw * !M``, ``M = |dw| >= c``.

Threshold selection (dynamic k) is a 48-step magnitude bisection — a
sort-free O(d log(range)) scheme that vectorizes cleanly on 8x128 VPU lanes,
unlike a full sort.  The mask/split itself is the Pallas kernel; it is purely
elementwise and tiles the d-vector in 128-lane blocks.

VMEM: 3 d-vectors + O(1) scalars; d <= 8192 => < 100 KiB.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _mask_split_kernel(w_ref, thr_ref, filt_ref, resid_ref):
    w = w_ref[...]
    keep = jnp.abs(w) >= thr_ref[0]
    filt_ref[...] = jnp.where(keep, w, 0.0)
    resid_ref[...] = jnp.where(keep, 0.0, w)


def mask_split(delta_w, threshold):
    """Apply mask M = |dw| >= threshold; returns (filtered, residual)."""
    d = delta_w.shape[0]
    thr = jnp.reshape(jnp.asarray(threshold, delta_w.dtype), (1,))
    return pl.pallas_call(
        _mask_split_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((d,), delta_w.dtype),
            jax.ShapeDtypeStruct((d,), delta_w.dtype),
        ),
        interpret=True,
    )(delta_w, thr)


@jax.jit
def topk_filter(delta_w, k):
    """Full filter: bisection threshold (dynamic k) + Pallas mask/split.

    Returns (filtered, residual, threshold).  ``filtered + residual ==
    delta_w`` exactly; support(filtered) <= k up to magnitude ties within the
    bisection resolution.
    """
    c = ref.topk_threshold_bisect(delta_w, k)
    filt, resid = mask_split(delta_w, c)
    return filt, resid, c
