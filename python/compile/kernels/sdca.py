"""L1 Pallas kernel: the local SDCA epoch (the paper's compute hot-spot).

Algorithm 2 line 4: solve the local subproblem G_k^{sigma'} for H stochastic
coordinate-ascent steps.  For the square loss (ridge regression, the paper's
experiment) each 1-D subproblem has the closed form

    delta = (y_i - alpha_i - x_i.(w_eff + u)) / (1 + sigma' ||x_i||^2 / lam_n)

TPU mapping (see DESIGN.md §Hardware-Adaptation): the epoch is inherently
sequential in H, so the kernel is a single program (grid=()) that keeps the
mutable d-vector ``u`` and the duals VMEM-resident across all H steps — the
analogue of the paper's C++ worker keeping w hot in L2 cache — and streams a
single row A[i, :] from the (VMEM-resident, n_k*d <= ~4 MiB per variant)
partition per step.  Dot products are VPU lane reductions.  ``interpret=True``
everywhere: the CPU PJRT plugin cannot execute Mosaic custom-calls, so the
kernel lowers to plain HLO (while-loop + dynamic-slice) that both pytest and
the rust runtime can run.

VMEM budget per shape variant (f32):
    A: n_k*d*4   y/alpha/sqnorms: 3*n_k*4   w_eff,u: 2*d*4   idx: H*4
    e.g. n_k=2048, d=1024: 8.0 MiB + 24 KiB + 8 KiB  << 16 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sdca_kernel(
    a_ref,        # (n_k, d) f32   data partition
    y_ref,        # (n_k,)   f32   labels
    alpha_ref,    # (n_k,)   f32   duals in
    weff_ref,     # (d,)     f32   w_k + gamma*delta_w_k
    idx_ref,      # (H,)     i32   coordinate schedule
    sqn_ref,      # (n_k,)   f32   ||x_i||^2
    scal_ref,     # (2,)     f32   [lam_n, sigma_prime]
    alpha_out,    # (n_k,)   f32   duals out
    ww_out,       # (d,)     f32   w_eff + sigma'/(lam n) * A^T dalpha
):
    lam_n = scal_ref[0]
    sig = scal_ref[1]
    scale = sig / lam_n

    alpha_out[...] = alpha_ref[...]
    # §Perf (L1): maintain the margin source ww = w_eff + u as ONE
    # VMEM-resident accumulator instead of re-forming w_eff + u from two
    # d-vectors every step — halves the per-step d-vector traffic
    # (EXPERIMENTS.md §Perf: ~1.9x epoch time on the lowered HLO).
    ww_out[...] = weff_ref[...]

    def body(h, _):
        i = idx_ref[h]
        x = pl.load(a_ref, (i, slice(None)))
        a_i = pl.load(alpha_out, (i,))
        y_i = pl.load(y_ref, (i,))
        q_i = pl.load(sqn_ref, (i,))
        z = jnp.dot(x, ww_out[...])
        delta = (y_i - a_i - z) / (1.0 + sig * q_i / lam_n)
        pl.store(alpha_out, (i,), a_i + delta)
        ww_out[...] = ww_out[...] + scale * delta * x
        return 0

    jax.lax.fori_loop(0, idx_ref.shape[0], body, 0)


@functools.partial(jax.jit, static_argnames=())
def sdca_epoch(A, y, alpha, w_eff, idx, sqnorms, lam_n, sigma_prime):
    """Pallas-backed SDCA epoch; signature mirrors ``ref.sdca_epoch``.

    Returns ``(alpha_new, delta_w)`` with
    ``delta_w = (1/(lam n)) A^T (alpha_new - alpha)``.
    """
    n_k, d = A.shape
    scalars = jnp.stack(
        [jnp.asarray(lam_n, jnp.float32), jnp.asarray(sigma_prime, jnp.float32)]
    )
    alpha_new, ww = pl.pallas_call(
        _sdca_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((n_k,), jnp.float32),
            jax.ShapeDtypeStruct((d,), jnp.float32),
        ),
        interpret=True,
    )(A, y, alpha, w_eff, idx.astype(jnp.int32), sqnorms, scalars)
    # ww = w_eff + u, u = sigma'/(lam n) A^T dalpha  =>  delta_w = u / sigma'
    delta_w = (ww - w_eff) / jnp.asarray(sigma_prime, jnp.float32)
    return alpha_new, delta_w
