//! End-to-end driver (EXPERIMENTS.md §E2E): train ridge regression on the
//! dense-e2e workload (n=8192, d=1024, ~8.4M parameters-equivalent data
//! tiles) for a few hundred communication rounds with ALL THREE LAYERS in
//! the loop:
//!
//!   L3 rust coordinator (Algorithm 1/2, group-wise + top-ρd messages)
//!   L2 jax graphs (sdca_epoch / objectives), AOT-lowered to HLO text
//!   L1 pallas kernels inside those graphs (interpret-mode, plain-HLO)
//!
//! Logs the duality-gap curve to results/e2e_gap.csv, compares against the
//! same run on the pure-rust solver (backend parity), and fails loudly if
//! the system does not converge.
//!
//!   cargo run --release --example train_e2e

use std::sync::Arc;

use acpd::data::synthetic::Preset;
use acpd::engine::EngineConfig;
use acpd::network::NetworkModel;
use acpd::runtime::{find_artifacts_dir, ArtifactRuntime, PjrtSolver};

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let ds = Preset::DenseE2e.generate(42);
    println!("data:   {}", ds.summary());

    // e2e artifact variant: nk=2048, d=1024, h=2048 => K = 8192/2048 = 4
    let mut cfg = EngineConfig::acpd(4, 2, 10, 1e-3);
    cfg.rho_d = 128; // 12.5% of coordinates per message
    cfg.h = 2048;
    cfg.outer_rounds = 30; // 300 communication rounds
    cfg.eval_every = 2;
    println!("engine: {}", cfg.describe());

    let dir = find_artifacts_dir()
        .ok_or_else(|| anyhow::anyhow!("artifacts/ missing — run `make artifacts`"))?;
    let rt = Arc::new(ArtifactRuntime::load_variant(dir, "e2e")?);
    println!("pjrt:   platform={}", rt.client().platform_name());

    // straggler + jitter: the conditions the paper's system is built for
    let net = NetworkModel::lan().with_straggler(4, 0, 4.0);

    let (lambda, sigma, gamma, n) = (cfg.lambda, cfg.sigma_prime, cfg.gamma, ds.n());
    let pjrt_out =
        acpd::sim::run_with_solvers(&ds, &cfg, &net, 7, |part, rng| {
            Box::new(
                PjrtSolver::new(rt.clone(), part, lambda, n, sigma, gamma, rng)
                    .expect("artifact shapes must fit"),
            )
        })?;
    let host_secs = t0.elapsed().as_secs_f64();

    println!("\nPJRT path — gap trajectory:");
    print!("{}", pjrt_out.history.render(15));

    // backend parity: same protocol and seeds on the pure-rust solver
    let rust_out = acpd::sim::run(&ds, &cfg, &net, 7);
    let final_pjrt = pjrt_out.history.last_gap();
    let final_rust = rust_out.history.last_gap();
    println!(
        "final gap: pjrt {final_pjrt:.3e} | rust {final_rust:.3e} (same seeds, same schedule)"
    );

    std::fs::create_dir_all("results").ok();
    pjrt_out.history.to_csv().save("results/e2e_gap.csv")?;
    rust_out.history.to_csv().save("results/e2e_gap_rust.csv")?;
    println!(
        "wrote results/e2e_gap.csv ({} points); host wall time {host_secs:.1}s, \
         simulated cluster time {:.1}s, {:.2} MB up",
        pjrt_out.history.points.len(),
        pjrt_out.stats.wall_time,
        pjrt_out.stats.bytes_up as f64 / 1e6,
    );

    anyhow::ensure!(final_pjrt < 1e-3, "e2e run did not converge: {final_pjrt:.3e}");
    let ratio = (final_pjrt / final_rust).max(final_rust / final_pjrt);
    anyhow::ensure!(
        ratio < 50.0,
        "backends disagree: pjrt {final_pjrt:.3e} vs rust {final_rust:.3e}"
    );
    println!("OK");
    Ok(())
}
