//! Worker-scaling study (Fig 4b in miniature): time to reach a target
//! duality gap as K grows, ACPD (B=K/2, ρd=10³, T=10) vs CoCoA+.
//!
//! Paper finding: CoCoA+ stops scaling as K grows (communication-bound);
//! ACPD keeps improving because both its per-round latency (group-wise)
//! and bytes (top-ρd) shrink the synchronization cost.
//!
//!   cargo run --release --example scaling_workers

use acpd::data::synthetic::Preset;
use acpd::engine::EngineConfig;
use acpd::network::NetworkModel;

fn main() -> anyhow::Result<()> {
    let mut spec = Preset::Rcv1Small.spec();
    spec.n = 8000;
    let ds = acpd::data::synthetic::generate(&spec, 42);
    let target = 1e-4;
    println!("data: {}  |  target gap = {target:.0e}\n", ds.summary());

    println!(
        "{:>4} {:>14} {:>14} {:>10}",
        "K", "ACPD time(s)", "CoCoA+ time(s)", "speedup"
    );
    for k in [2usize, 4, 8, 16] {
        let mut acpd_cfg = EngineConfig::acpd(k, (k / 2).max(1), 10, 1e-3);
        acpd_cfg.rho_d = 1000;
        acpd_cfg.h = 10_000;
        acpd_cfg.outer_rounds = 10_000;
        acpd_cfg.target_gap = target;
        acpd_cfg.eval_every = 2;

        let mut cocoa_cfg = EngineConfig::cocoa_plus(k, 1e-3);
        cocoa_cfg.h = 10_000;
        cocoa_cfg.outer_rounds = 100_000;
        cocoa_cfg.target_gap = target;
        cocoa_cfg.eval_every = 2;

        let net = NetworkModel::lan(); // sigma = 1 per the paper's Fig 4b
        let a = acpd::sim::run(&ds, &acpd_cfg, &net, 7);
        let c = acpd::sim::run(&ds, &cocoa_cfg, &net, 7);
        let ta = a.history.time_to_gap(target).map(|(_, t)| t);
        let tc = c.history.time_to_gap(target).map(|(_, t)| t);
        match (ta, tc) {
            (Some(ta), Some(tc)) => {
                println!("{k:>4} {ta:>14.2} {tc:>14.2} {:>9.2}x", tc / ta)
            }
            _ => println!("{k:>4} {ta:>14.2?} {tc:>14.2?}      n/a"),
        }
    }
    Ok(())
}
