//! Quickstart: the smallest end-to-end use of the public API.
//!
//! Runs ACPD (4 workers, group size 2) on a dense synthetic problem where
//! each worker's local solve executes the AOT-compiled JAX/Pallas kernels
//! through PJRT — python is NOT running; the artifacts were produced once by
//! `make artifacts`.
//!
//!   cargo run --release --example quickstart

use std::sync::Arc;

use acpd::data::synthetic::Preset;
use acpd::engine::EngineConfig;
use acpd::network::NetworkModel;
use acpd::runtime::{find_artifacts_dir, ArtifactRuntime, PjrtSolver};

fn main() -> anyhow::Result<()> {
    // 1. a dataset — dense preset matching the `quickstart` artifact shapes
    //    (n=4096 over K=4 workers -> nk=1024, d=512)
    let mut spec = Preset::DenseE2e.spec();
    spec.name = "quickstart-dense";
    spec.n = 4096;
    spec.d = 512;
    let ds = acpd::data::synthetic::generate(&spec, 42);
    println!("data:   {}", ds.summary());

    // 2. an algorithm config — ACPD with the paper's sigma' = gamma*B
    let mut cfg = EngineConfig::acpd(4, 2, 10, 1e-3);
    cfg.rho_d = 64; // ship only 64 of 512 coordinates per message
    cfg.h = 1024; // one artifact epoch per round
    cfg.outer_rounds = 6;
    println!("engine: {}", cfg.describe());

    // 3. the compute backend — AOT JAX/Pallas artifacts on the PJRT client
    let dir = find_artifacts_dir()
        .ok_or_else(|| anyhow::anyhow!("artifacts/ missing — run `make artifacts`"))?;
    let rt = Arc::new(ArtifactRuntime::load_variant(dir, "quickstart")?);
    println!(
        "pjrt:   platform={} artifacts={}",
        rt.client().platform_name(),
        rt.manifest().entries.len()
    );

    // 4. run the full protocol in the deterministic cluster simulator
    let (lambda, sigma, gamma, n) = (cfg.lambda, cfg.sigma_prime, cfg.gamma, ds.n());
    let out = acpd::sim::run_with_solvers(&ds, &cfg, &NetworkModel::lan(), 7, |part, rng| {
        Box::new(
            PjrtSolver::new(rt.clone(), part, lambda, n, sigma, gamma, rng)
                .expect("artifact shapes must fit the partition"),
        )
    })?;

    println!("\nduality-gap trajectory (every 10th round):");
    print!("{}", out.history.render(10));
    println!(
        "final gap {:.3e} after {} rounds — {:.2} MB up ({} B/round avg, dense would be {} B/round)",
        out.history.last_gap(),
        out.stats.rounds,
        out.stats.bytes_up as f64 / 1e6,
        out.history.mean_bytes_up_per_round() as u64,
        4 * ds.d()
    );
    anyhow::ensure!(out.history.last_gap() < 0.05, "quickstart failed to converge");
    println!("OK");
    Ok(())
}
