//! Regenerate the paper's four experiment grids (Figures 3, 4a, 4b and
//! Figure 5 / Table I) as scenario sweeps — every grid is one declarative
//! `SweepSpec` executed in parallel across all cores, with per-figure CSVs
//! and ranked comparison tables written to `results/paper/`.
//!
//!   cargo run --release --example paper_figures
//!   ACPD_FIGS_FAST=1 cargo run --release --example paper_figures   (~10x smaller)
//!
//! The equivalent one-off CLI form of the Fig 3 grid:
//!
//!   acpd sweep --algos acpd,cocoa,cocoa+ --scenarios lan,straggler:10 \
//!        --datasets rcv1-small --rho-ds 1000 --seeds 1,2,3 --target-gap 1e-4
//!
//! (and Fig 4b's whole K ∈ {2,4,8,16} scaling curve is a single matrix:
//! `--workers 2,4,8,16 --group 0` — group 0 keeps B = K/2 per point)

use acpd::data::synthetic::Preset;
use acpd::data::DatasetSource;
use acpd::engine::Algorithm;
use acpd::network::Scenario;
use acpd::sweep::{run_sweep, SweepReport, SweepSpec};

fn fast() -> bool {
    std::env::var("ACPD_FIGS_FAST").map(|v| v == "1").unwrap_or(false)
}

fn out_dir() -> std::path::PathBuf {
    let p = std::path::PathBuf::from("results/paper");
    std::fs::create_dir_all(&p).ok();
    p
}

/// Shared baseline grid: rcv1-shaped data, K = 4, the paper's B = K/2 and
/// T = 10, time-to-1e-4-gap as the headline metric.
fn base() -> SweepSpec {
    let mut s = SweepSpec::default();
    s.datasets = vec![DatasetSource::Preset(Preset::Rcv1Small)];
    s.workers = vec![4];
    s.groups = vec![2];
    s.periods = vec![10];
    s.lambda = 1e-4;
    s.target_gap = 1e-4;
    s.seeds = vec![1, 2, 3];
    if fast() {
        s.n_override = 2000;
        s.d_override = 5000;
        s.h = 1000;
        s.outer_rounds = 30;
    } else {
        s.h = 10_000;
        s.outer_rounds = 60;
    }
    s
}

fn save(report: &SweepReport, stem: &str) -> anyhow::Result<()> {
    let dir = out_dir();
    report.cells_csv().save(dir.join(format!("{stem}_cells.csv")))?;
    report.ranked_csv().save(dir.join(format!("{stem}_ranked.csv")))?;
    std::fs::write(dir.join(format!("{stem}.json")), report.to_json())?;
    eprintln!("wrote results/paper/{stem}_{{cells,ranked}}.csv + {stem}.json");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    // ---- Fig 3: convergence vs rounds/time, sigma in {1, 10} ------------
    // sigma=1 is straggler:1 (same compute-dominated machine, no slow
    // worker), NOT lan — otherwise the cross-sigma time axis would also
    // carry a 100x flop_time regime change (see network::Scenario docs).
    let mut fig3 = base();
    fig3.algorithms = vec![Algorithm::Acpd, Algorithm::Cocoa, Algorithm::CocoaPlus];
    fig3.scenarios = vec![
        Scenario::Straggler { sigma: 1.0 },
        Scenario::Straggler { sigma: 10.0 },
    ];
    fig3.rho_ds = vec![1000];
    eprintln!("[fig3] {}", fig3.describe());
    let r3 = run_sweep(&fig3)?;
    save(&r3, "fig3")?;
    print!("{}", r3.render());

    // ---- Fig 4a: message sparsity rho_d sweep (ACPD) --------------------
    let mut fig4a = base();
    fig4a.algorithms = vec![Algorithm::Acpd, Algorithm::CocoaPlus];
    fig4a.scenarios = vec![Scenario::Lan];
    fig4a.rho_ds = vec![0, 100, 1000, 10_000];
    eprintln!("[fig4a] {}", fig4a.describe());
    let r4a = run_sweep(&fig4a)?;
    save(&r4a, "fig4a")?;

    // ---- Fig 4b: worker scaling K in {2, 4, 8, 16} ----------------------
    // workers is a grid axis, so the whole scaling curve is ONE matrix;
    // group = 0 keeps the paper's B = K/2 coupling per point, and the
    // ranked table yields one comparison block per K (speedup curves come
    // from the per-cell CSV's workers column).
    let mut fig4b = base();
    fig4b.algorithms = vec![Algorithm::Acpd, Algorithm::CocoaPlus];
    fig4b.scenarios = vec![Scenario::Straggler { sigma: 10.0 }];
    fig4b.rho_ds = vec![1000];
    fig4b.workers = vec![2, 4, 8, 16];
    fig4b.groups = vec![0]; // auto: B = max(K/2, 1) at every K
    eprintln!("[fig4b] {}", fig4b.describe());
    let r4b = run_sweep(&fig4b)?;
    save(&r4b, "fig4b")?;

    // ---- Fig 5 / Table I: "real environment" (background jitter) -------
    let mut fig5 = base();
    fig5.algorithms = vec![
        Algorithm::Acpd,
        Algorithm::Cocoa,
        Algorithm::CocoaPlus,
        Algorithm::DisDca,
    ];
    fig5.scenarios = vec![Scenario::JitteryCloud];
    fig5.rho_ds = vec![0, 1000]; // Table I: dense vs filtered bytes
    eprintln!("[fig5] {}", fig5.describe());
    let r5 = run_sweep(&fig5)?;
    save(&r5, "fig5_table1")?;
    print!("{}", r5.render());

    eprintln!("all four grids regenerated under results/paper/");
    Ok(())
}
