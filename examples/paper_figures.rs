//! Regenerate the paper's four experiment grids (Figures 3, 4a, 4b and
//! Figure 5 / Table I) as scenario sweeps — every grid is one declarative
//! `SweepSpec` executed in parallel across all cores, with per-figure CSVs
//! and ranked comparison tables written to `results/paper/`.
//!
//!   cargo run --release --example paper_figures
//!   ACPD_FIGS_FAST=1 cargo run --release --example paper_figures   (~10x smaller)
//!
//! The equivalent one-off CLI form of the Fig 3 grid:
//!
//!   acpd sweep --algos acpd,cocoa,cocoa+ --scenarios lan,straggler:10 \
//!        --presets rcv1-small --rho-ds 1000 --seeds 1,2,3 --target-gap 1e-4

use acpd::data::synthetic::Preset;
use acpd::engine::Algorithm;
use acpd::network::Scenario;
use acpd::sweep::{run_sweep, SweepReport, SweepSpec};

fn fast() -> bool {
    std::env::var("ACPD_FIGS_FAST").map(|v| v == "1").unwrap_or(false)
}

fn out_dir() -> std::path::PathBuf {
    let p = std::path::PathBuf::from("results/paper");
    std::fs::create_dir_all(&p).ok();
    p
}

/// Shared baseline grid: rcv1-shaped data, K = 4, the paper's B = K/2 and
/// T = 10, time-to-1e-4-gap as the headline metric.
fn base() -> SweepSpec {
    let mut s = SweepSpec::default();
    s.presets = vec![Preset::Rcv1Small];
    s.workers = 4;
    s.group = 2;
    s.period = 10;
    s.lambda = 1e-4;
    s.target_gap = 1e-4;
    s.seeds = vec![1, 2, 3];
    if fast() {
        s.n_override = 2000;
        s.d_override = 5000;
        s.h = 1000;
        s.outer_rounds = 30;
    } else {
        s.h = 10_000;
        s.outer_rounds = 60;
    }
    s
}

fn save(report: &SweepReport, stem: &str) -> anyhow::Result<()> {
    let dir = out_dir();
    report.cells_csv().save(dir.join(format!("{stem}_cells.csv")))?;
    report.ranked_csv().save(dir.join(format!("{stem}_ranked.csv")))?;
    std::fs::write(dir.join(format!("{stem}.json")), report.to_json())?;
    eprintln!("wrote results/paper/{stem}_{{cells,ranked}}.csv + {stem}.json");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    // ---- Fig 3: convergence vs rounds/time, sigma in {1, 10} ------------
    // sigma=1 is straggler:1 (same compute-dominated machine, no slow
    // worker), NOT lan — otherwise the cross-sigma time axis would also
    // carry a 100x flop_time regime change (see network::Scenario docs).
    let mut fig3 = base();
    fig3.algorithms = vec![Algorithm::Acpd, Algorithm::Cocoa, Algorithm::CocoaPlus];
    fig3.scenarios = vec![
        Scenario::Straggler { sigma: 1.0 },
        Scenario::Straggler { sigma: 10.0 },
    ];
    fig3.rho_ds = vec![1000];
    eprintln!("[fig3] {}", fig3.describe());
    let r3 = run_sweep(&fig3)?;
    save(&r3, "fig3")?;
    print!("{}", r3.render());

    // ---- Fig 4a: message sparsity rho_d sweep (ACPD) --------------------
    let mut fig4a = base();
    fig4a.algorithms = vec![Algorithm::Acpd, Algorithm::CocoaPlus];
    fig4a.scenarios = vec![Scenario::Lan];
    fig4a.rho_ds = vec![0, 100, 1000, 10_000];
    eprintln!("[fig4a] {}", fig4a.describe());
    let r4a = run_sweep(&fig4a)?;
    save(&r4a, "fig4a")?;

    // ---- Fig 4b: worker scaling K in {2, 4, 8, 16} ----------------------
    // workers is a shared knob, so scaling is one sweep per K; the cells
    // carry a `workers` column and are merged into a single report.
    let mut all_cells = Vec::new();
    for k in [2usize, 4, 8, 16] {
        let mut s = base();
        s.algorithms = vec![Algorithm::Acpd, Algorithm::CocoaPlus];
        s.scenarios = vec![Scenario::Straggler { sigma: 10.0 }];
        s.rho_ds = vec![1000];
        s.workers = k;
        s.group = (k / 2).max(1);
        eprintln!("[fig4b K={k}] {}", s.describe());
        let r = run_sweep(&s)?;
        let offset = all_cells.len();
        all_cells.extend(r.cells.into_iter().map(|mut c| {
            c.index += offset; // keep indices unique across the K sub-grids
            c
        }));
    }
    let r4b = SweepReport::new("fig4b: worker scaling K in {2,4,8,16}".to_string(), all_cells);
    // ranked()/to_json() group by (scenario, preset, rho_d) — averaging
    // across different K under one key would be meaningless — so fig4b
    // ships the per-cell CSV only (speedup curves live there).
    r4b.cells_csv().save(out_dir().join("fig4b_cells.csv"))?;
    eprintln!("wrote results/paper/fig4b_cells.csv");

    // ---- Fig 5 / Table I: "real environment" (background jitter) -------
    let mut fig5 = base();
    fig5.algorithms = vec![
        Algorithm::Acpd,
        Algorithm::Cocoa,
        Algorithm::CocoaPlus,
        Algorithm::DisDca,
    ];
    fig5.scenarios = vec![Scenario::JitteryCloud];
    fig5.rho_ds = vec![0, 1000]; // Table I: dense vs filtered bytes
    eprintln!("[fig5] {}", fig5.describe());
    let r5 = run_sweep(&fig5)?;
    save(&r5, "fig5_table1")?;
    print!("{}", r5.render());

    eprintln!("all four grids regenerated under results/paper/");
    Ok(())
}
