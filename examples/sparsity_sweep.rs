//! Sparsity-constant study (Fig 4a in miniature): how does the per-message
//! coordinate budget ρd affect convergence *per communication round*?
//!
//! The paper's finding: curves for ρd from 10⁴ down to 10 overlap until the
//! gap reaches ~10⁻⁴; only far below that does heavy compression bite —
//! i.e. ACPD is robust to the choice of ρ.
//!
//!   cargo run --release --example sparsity_sweep

use acpd::data::synthetic::Preset;
use acpd::engine::EngineConfig;
use acpd::network::NetworkModel;

fn main() -> anyhow::Result<()> {
    let mut spec = Preset::Rcv1Small.spec();
    spec.n = 8000;
    let ds = acpd::data::synthetic::generate(&spec, 42);
    println!("data: {}\n", ds.summary());

    let rho_ds = [0usize, 10_000, 1000, 100, 10]; // 0 = dense baseline
    let checkpoints = [50u64, 100, 200, 400];

    println!(
        "{:<12} {}",
        "rho_d",
        checkpoints
            .iter()
            .map(|r| format!("{:>12}", format!("gap@r{r}")))
            .collect::<String>()
    );
    for &rho_d in &rho_ds {
        let mut cfg = EngineConfig::acpd(4, 2, 20, 1e-3);
        cfg.rho_d = rho_d;
        cfg.h = 4000;
        cfg.outer_rounds = 25; // 25*20 = 500 rounds
        cfg.eval_every = 5;
        let out = acpd::sim::run(&ds, &cfg, &NetworkModel::lan(), 7);
        let label = if rho_d == 0 { "dense".into() } else { format!("{rho_d}") };
        let row: String = checkpoints
            .iter()
            .map(|&r| {
                let gap = out
                    .history
                    .points
                    .iter()
                    .filter(|p| p.round <= r)
                    .next_back()
                    .map(|p| p.gap)
                    .unwrap_or(f64::NAN);
                format!("{gap:>12.2e}")
            })
            .collect();
        println!("{label:<12} {row}");
    }
    println!("\n(expect: rows nearly identical until gap ~1e-4 — robustness to rho)");
    Ok(())
}
