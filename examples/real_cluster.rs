//! Real distributed run: one coordinator + K worker OS PROCESSES talking
//! length-prefixed frames over real TCP sockets on localhost — the
//! reproduction of the paper's OpenMPI deployment (§V-C), with worker 0
//! physically sleeping 5x as the straggler.
//!
//!   cargo run --release --example real_cluster
//!
//! (This example shells out to the `acpd` binary's `server`/`worker`
//! subcommands, so it exercises the exact CLI a real deployment would use.)

use std::process::{Command, Stdio};

fn acpd_bin() -> std::path::PathBuf {
    // target/<profile>/examples/real_cluster -> target/<profile>/acpd
    let mut p = std::env::current_exe().expect("current_exe");
    p.pop(); // real_cluster
    p.pop(); // examples/
    p.push("acpd");
    p
}

fn main() -> anyhow::Result<()> {
    let bin = acpd_bin();
    anyhow::ensure!(
        bin.exists(),
        "{} missing — run `cargo build --release` first",
        bin.display()
    );
    let addr = "127.0.0.1:47311";
    let k = 3;
    let common: Vec<String> = [
        "--preset",
        "rcv1-small",
        "--workers",
        "3",
        "--group",
        "2",
        "--period",
        "5",
        "--rho-d",
        "1000",
        "--h",
        "2000",
        "--lambda",
        "1e-3",
        "--outer-rounds",
        "6",
        "--straggler-worker",
        "0",
        "--straggler-factor",
        "5",
        "--addr",
        addr,
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();

    println!("spawning coordinator on {addr} ...");
    let mut server = Command::new(&bin)
        .arg("server")
        .args(&common)
        .stdout(Stdio::inherit())
        .stderr(Stdio::inherit())
        .spawn()?;
    std::thread::sleep(std::time::Duration::from_millis(300));

    println!("spawning {k} worker processes ...");
    let mut workers = Vec::new();
    for wid in 0..k {
        workers.push(
            Command::new(&bin)
                .arg("worker")
                .args(&common)
                .args(["--id", &wid.to_string()])
                .stdout(Stdio::inherit())
                .stderr(Stdio::inherit())
                .spawn()?,
        );
    }
    let status = server.wait()?;
    for mut w in workers {
        let _ = w.wait();
    }
    anyhow::ensure!(status.success(), "server exited with {status}");
    println!("real_cluster OK");
    Ok(())
}
