//! Straggler study (the paper's headline scenario, Fig 3 in miniature).
//!
//! Compares ACPD against CoCoA+ and the two ablations (B=K: no
//! straggler-agnosticism; ρ=1: no compression) on an rcv1-like workload
//! with a σ× slow worker, reporting simulated time to reach a target
//! duality gap.
//!
//!   cargo run --release --example straggler_sim [sigma] [target_gap]

use acpd::data::synthetic::Preset;
use acpd::engine::EngineConfig;
use acpd::network::NetworkModel;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let sigma: f64 = args.first().map(|s| s.parse()).transpose()?.unwrap_or(10.0);
    let target: f64 = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(1e-4);

    let mut spec = Preset::Rcv1Small.spec();
    spec.n = 8000; // keep the example snappy; bench fig3 runs the full size
    let ds = acpd::data::synthetic::generate(&spec, 42);
    println!("data: {}  |  straggler sigma = {sigma}  |  target gap = {target:.0e}\n", ds.summary());

    let k = 4;
    let lambda = 1e-3;
    let mk = |label: &str, mut cfg: EngineConfig| {
        cfg.h = 4000;
        cfg.outer_rounds = 4000;
        cfg.target_gap = target;
        (label.to_string(), cfg)
    };
    let candidates = vec![
        mk("ACPD (B=2, rho_d=1e3, T=20)", {
            let mut c = EngineConfig::acpd(k, 2, 20, lambda);
            c.rho_d = 1000;
            c
        }),
        mk("ACPD B=K (no straggler-agn.)", {
            let mut c = EngineConfig::acpd(k, k, 20, lambda);
            c.recouple_sigma();
            c.rho_d = 1000;
            c
        }),
        mk("ACPD rho=1 (no compression)", {
            let mut c = EngineConfig::acpd(k, 2, 20, lambda);
            c.rho_d = 0;
            c
        }),
        mk("CoCoA+", EngineConfig::cocoa_plus(k, lambda)),
    ];

    let net = NetworkModel::lan().with_straggler(k, 0, sigma);
    println!(
        "{:<32} {:>8} {:>12} {:>12} {:>10}",
        "algorithm", "rounds", "time(s)", "MB up", "gap"
    );
    let mut times = Vec::new();
    for (label, cfg) in candidates {
        let out = acpd::sim::run(&ds, &cfg, &net, 7);
        match out.history.time_to_gap(target) {
            Some((round, time)) => {
                println!(
                    "{:<32} {:>8} {:>12.2} {:>12.2} {:>10.1e}",
                    label,
                    round,
                    time,
                    out.stats.bytes_up as f64 / 1e6,
                    out.history.last_gap()
                );
                times.push((label, time));
            }
            None => println!(
                "{:<32} {:>8} {:>12} {:>12.2} {:>10.1e}",
                label,
                out.stats.rounds,
                "did not reach",
                out.stats.bytes_up as f64 / 1e6,
                out.history.last_gap()
            ),
        }
    }
    if let (Some(acpd), Some(cocoa)) = (
        times.iter().find(|(l, _)| l.starts_with("ACPD (")),
        times.iter().find(|(l, _)| l.starts_with("CoCoA+")),
    ) {
        println!(
            "\nACPD speedup over CoCoA+ at sigma={sigma}: {:.2}x",
            cocoa.1 / acpd.1
        );
    }
    Ok(())
}
